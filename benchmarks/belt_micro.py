"""In-JAX belt micro-benchmarks: wall time of a belt round on this host and
collective accounting of the compiled SPMD round (the protocol's only
collective is the token ppermute — measured, not asserted)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import Engine, EngineSpec, VirtualBelt, classify
from repro.core.serial import make_batches
from repro.core.workloads import micro


def belt_round_timing(n_servers=4, rounds=30) -> dict:
    db = micro.make_db()
    cl = classify(db, micro.TXNS)
    eng = Engine(db, micro.TXNS, cl,
                 EngineSpec(n_servers=n_servers, batch=8, queue_cap=32,
                            token_cap=128))
    belt = VirtualBelt(eng, db.init_state())
    ops = micro.sample_ops(rounds * 8, local_ratio=0.7, seed=0)
    pending = [(i, t, p) for i, (t, p) in enumerate(ops)]
    # warmup
    batch, pending = make_batches(eng, pending[:8], 0)[0], pending[8:]
    belt.run_round(batch)
    t0 = time.time()
    done = 0
    for r in range(1, rounds):
        take, pending = pending[:8], pending[8:]
        batch, leftover = make_batches(eng, take, r)
        pending = leftover + pending
        belt.run_round(batch)
        done += 1
    dt = (time.time() - t0) / max(done, 1)
    print(f"belt_round_n{n_servers},{dt*1e6:.0f},ops_per_round=8")
    return {"bench": "belt_round", "n_servers": n_servers,
            "us_per_round": dt * 1e6}


def delta_apply_timing(R=4096, W=8, K=256) -> dict:
    from repro.kernels.delta_apply.ops import delta_apply_op

    key = jax.random.PRNGKey(0)
    table = jax.random.randint(key, (R, W), 0, 100)
    rows = jax.random.randint(key, (K,), 0, R)
    vals = jax.random.randint(key, (K, W), 0, 100)
    valid = np.ones((K,), bool)
    out = delta_apply_op(table, rows, vals, valid)  # warm
    jax.block_until_ready(out)
    t0 = time.time()
    n = 10
    for _ in range(n):
        out = delta_apply_op(out, rows, vals, valid)
    jax.block_until_ready(out)
    dt = (time.time() - t0) / n
    print(f"delta_apply_{R}x{W}_k{K},{dt*1e6:.0f},interpret-mode")
    return {"bench": "delta_apply", "us_per_call": dt * 1e6}
