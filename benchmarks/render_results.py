"""Render dryrun_*.json + roofline.json into the EXPERIMENTS.md tables.

Usage: PYTHONPATH=src python -m benchmarks.render_results
Prints markdown to stdout (pasted into EXPERIMENTS.md by the maintainer).
"""
from __future__ import annotations

import json
import sys

GIB = 2**30


def dryrun_table(path: str) -> str:
    rows = json.load(open(path))
    out = [
        "| arch | shape | mesh | compile | args/dev | temp/dev | flops/dev | coll/dev | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | — "
                f"| SKIP: {r['reason'][:40]} |"
            )
            continue
        if r["status"] == "error":
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | — "
                f"| ERROR |"
            )
            continue
        pd = r["per_device"]
        coll = sum(r["collectives"].values())
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']:.0f}s "
            f"| {pd['argument_bytes']/GIB:.2f} GiB | {pd['temp_bytes']/GIB:.2f} GiB "
            f"| {pd['flops']:.2e} | {coll/GIB:.2f} GiB | OK |"
        )
    return "\n".join(out)


def roofline_table(path: str) -> str:
    rows = json.load(open(path))
    out = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant "
        "| MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | — |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f} ms "
            f"| {r['t_memory_s']*1e3:.1f} ms | {r['t_collective_s']*1e3:.1f} ms "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']*100:.1f}% |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    for p in sys.argv[1:] or ("dryrun_1pod.json", "dryrun_2pod.json"):
        try:
            print(f"\n### {p}\n")
            print(dryrun_table(p))
        except FileNotFoundError:
            print(f"({p} missing)")
    try:
        print("\n### roofline.json\n")
        print(roofline_table("roofline.json"))
    except FileNotFoundError:
        print("(roofline.json missing)")
