"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (paper_tables), the protocol
micro-benchmarks (belt_micro), and the framework-level Conveyor-DP vs
all-reduce comparison.  Output: ``name,us_per_call,derived`` CSV lines plus
a results JSON.  Roofline extraction runs separately
(``python -m benchmarks.roofline``) because it compiles ~60 cells on 512
placeholder devices; if ``roofline.json`` is present its headline numbers
are summarized here.
"""
from __future__ import annotations

import json
import os
import sys


def main() -> None:
    rows = []
    print("name,us_per_call,derived")

    from benchmarks import paper_tables as pt

    rows += pt.table1_classification()
    rows += pt.fig3_lan_scaling()
    rows += pt.fig4_wan()
    rows += pt.table3_latency()
    rows += pt.fig5_local_ratio()

    from benchmarks import belt_micro as bm

    rows.append(bm.belt_round_timing())
    rows.append(bm.delta_apply_timing())

    from benchmarks import conveyor_dp_bench as cdp

    rows += cdp.run()

    for path in ("roofline.json", "/root/repo/roofline.json"):
        if os.path.exists(path):
            with open(path) as f:
                rl = json.load(f)
            done = [r for r in rl if "dominant" in r]
            if done:
                worst = min(done, key=lambda r: r.get("roofline_fraction", 1))
                best = max(done, key=lambda r: r.get("roofline_fraction", 0))
                print(f"roofline_summary,_,cells={len(done)}|"
                      f"best={best['arch']}:{best['shape']}="
                      f"{best['roofline_fraction']*100:.0f}%|"
                      f"worst={worst['arch']}:{worst['shape']}="
                      f"{worst['roofline_fraction']*100:.0f}%")
            break

    out = os.path.join(os.path.dirname(__file__), "results.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    print(f"# wrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
