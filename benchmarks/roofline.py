import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis (EXPERIMENTS.md §Roofline).

``cost_analysis`` counts a lax.scan body ONCE (verified), so scanned-layer
graphs undercount depth.  Methodology: compile UNROLLED shallow variants at
two depths (L₂ < L₄), take per-layer deltas

    per_layer = (cost(L₄) − cost(L₂)) / (L₄ − L₂)
    total(L)  = cost(L₂) + (L − L₂) × per_layer

for FLOPs, HBM bytes, and per-kind collective bytes — exact for homogeneous
stacks (all ours are).  Terms per chip (cost_analysis is per-device under
SPMD):

    t_compute    = FLOPs / 197e12        (bf16 peak)
    t_memory     = bytes / 819e9         (HBM bw)
    t_collective = coll_bytes / 50e9     (ICI per link)

MODEL_FLOPS = 6·N·D (train) or 2·N·D (prefill) or 2·N_active·B (decode);
the ratio MODEL/HLO exposes remat recompute + padding waste.

Run: ``PYTHONPATH=src python -m benchmarks.roofline [--mesh 1pod]
[--cells arch:shape,...] [--out roofline.json]``
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import all_cells, get_arch, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh, n_chips  # noqa: E402
from repro.launch.roofline import (  # noqa: E402
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    collective_bytes,
)
from repro.launch.steps import build_cell, lower_cell  # noqa: E402


def _depths(arch: str) -> tuple[int, int]:
    if arch == "zamba2-7b":
        return 6, 12  # one vs two (5 mamba + shared attn) groups
    return 2, 4


def _costs(arch, shape, mesh, n_layers, tuning, overrides=None):
    ov = dict(overrides or {})
    ov["n_layers"] = n_layers
    cell = build_cell(arch, shape, mesh, layer_mode="unroll",
                      overrides=ov, **tuning)
    compiled = lower_cell(cell).compile()
    ca = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": ca.get("flops", 0.0),
        "bytes": ca.get("bytes accessed", 0.0),
        "coll": sum(coll.values()),
        "coll_by_kind": coll,
    }, cell.cfg


def model_flops(cfg, shape, chips: int) -> float:
    toks = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    n = cfg.n_active_params() if cfg.n_experts else cfg.n_params()
    if shape.kind == "train":
        return 6.0 * n * toks / chips  # per-chip
    # inference: the LM head runs on ONE position per request, not per token
    n_embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    body = 2.0 * max(n - n_embed, 0) * toks
    head = 2.0 * cfg.vocab * cfg.d_model * shape.global_batch
    return (body + head) / chips


def analyze_cell(arch: str, shape_name: str, mesh, tuning=None,
                 overrides=None) -> dict:
    """tuning: build_cell kwargs (microbatches/opt_cfg); overrides: model
    config overrides (remat, attn_chunk, ...) — the §Perf knobs."""
    tuning = tuning or {}
    shape = get_shape(shape_name)
    l2, l4 = _depths(arch)
    c2, cfg2 = _costs(arch, shape_name, mesh, l2, tuning, overrides)
    c4, _ = _costs(arch, shape_name, mesh, l4, tuning, overrides)
    full_cfg = get_arch(arch, **(overrides or {}))
    L = full_cfg.n_layers

    out = {}
    for k in ("flops", "bytes", "coll"):
        per_layer = (c4[k] - c2[k]) / (l4 - l2)
        out[k] = c2[k] + (L - l2) * per_layer
        out[f"{k}_per_layer"] = per_layer
    out["coll_by_kind"] = {
        k: c2["coll_by_kind"][k]
        + (L - l2) * (c4["coll_by_kind"][k] - c2["coll_by_kind"][k]) / (l4 - l2)
        for k in c2["coll_by_kind"]
    }
    chips = n_chips(mesh)
    t_c = out["flops"] / PEAK_FLOPS
    t_m = out["bytes"] / HBM_BW
    t_x = out["coll"] / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    mf = model_flops(full_cfg, shape, chips)
    bound = max(t_c, t_m, t_x)
    return {
        "arch": arch, "shape": shape_name, "chips": chips,
        "hlo_flops_per_chip": out["flops"],
        "hbm_bytes_per_chip": out["bytes"],
        "coll_bytes_per_chip": out["coll"],
        "coll_by_kind": out["coll_by_kind"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom,
        "model_flops_per_chip": mf,
        "useful_ratio": mf / out["flops"] if out["flops"] else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=("1pod", "2pod"), default="1pod")
    ap.add_argument("--cells", default=None,
                    help="comma-separated arch:shape filters")
    ap.add_argument("--out", default="roofline.json")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.mesh == "2pod")
    want = None
    if args.cells:
        want = {tuple(c.split(":")) for c in args.cells.split(",")}

    from repro.launch.dryrun import CELL_TUNING  # shipped per-cell defaults

    results = []
    for arch, shape, ok, why in all_cells():
        if want is not None and (arch, shape) not in want:
            continue
        if not ok:
            results.append({"arch": arch, "shape": shape, "skipped": why})
            continue
        t0 = time.time()
        try:
            tuning = dict(CELL_TUNING.get((arch, shape), {}))
            overrides = tuning.pop("overrides", None)
            rec = analyze_cell(arch, shape, mesh, tuning=tuning,
                               overrides=overrides)
            rec["seconds"] = round(time.time() - t0, 1)
            print(
                f"{arch:22s} {shape:12s} dom={rec['dominant']:10s} "
                f"tc={rec['t_compute_s']*1e3:8.2f}ms tm={rec['t_memory_s']*1e3:8.2f}ms "
                f"tx={rec['t_collective_s']*1e3:8.2f}ms "
                f"useful={rec['useful_ratio']:.2f} "
                f"roofline={rec['roofline_fraction']*100:5.1f}%", flush=True,
            )
        except Exception as e:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "error": repr(e)[:500]}
            print(f"{arch:22s} {shape:12s} ERROR {e!r}", flush=True)
        results.append(rec)

    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    sys.exit(main())
