"""Inject rendered dry-run/roofline tables into EXPERIMENTS.md markers.

Usage: PYTHONPATH=src python -m benchmarks.update_experiments
Replaces <!-- DRYRUN_TABLES --> and <!-- ROOFLINE_TABLE --> in place.
"""
from __future__ import annotations

import json

from benchmarks.render_results import dryrun_table, roofline_table

PATH = "EXPERIMENTS.md"


def main():
    src = open(PATH).read()

    dr = []
    for mesh, path in (("16×16 single-pod", "dryrun_1pod.json"),
                       ("2×16×16 multi-pod", "dryrun_2pod.json")):
        try:
            dr.append(f"\n#### {mesh}\n\n" + dryrun_table(path))
        except FileNotFoundError:
            dr.append(f"\n#### {mesh}\n\n(pending)")
    src = src.replace("<!-- DRYRUN_TABLES -->", "\n".join(dr), 1)

    try:
        rl = roofline_table("roofline.json")
        # headline roofline numbers
        rows = [r for r in json.load(open("roofline.json")) if "dominant" in r]
        best = max(rows, key=lambda r: r["roofline_fraction"])
        head = (f"\n**Headline**: best cell "
                f"{best['arch']}:{best['shape']} at "
                f"{best['roofline_fraction']*100:.1f}% of roofline; "
                f"{sum(1 for r in rows if r['dominant']=='memory')} cells "
                f"memory-bound, "
                f"{sum(1 for r in rows if r['dominant']=='collective')} "
                f"collective-bound, "
                f"{sum(1 for r in rows if r['dominant']=='compute')} "
                f"compute-bound.\n\n")
        src = src.replace("<!-- ROOFLINE_TABLE -->", head + rl, 1)
    except FileNotFoundError:
        pass

    open(PATH, "w").write(src)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
