"""Paper-table benchmarks (one function per table/figure of the paper).

All use the calibrated host-level simulator (core.hostsim) driven by the
REAL classified workloads — same Algorithm 1, same routing as the JAX belt.
Each returns rows of dicts and prints `name,us_per_call,derived` CSV lines
(us_per_call = mean request latency µs; derived = headline ratio).
"""
from __future__ import annotations

import numpy as np

from repro.core import Engine, EngineSpec, classify
from repro.core.hostsim import op_source_from_workload, peak_throughput, simulate
from repro.core.workloads import micro, rubis, tpcw

CLIENTS = (16, 64, 256)
DUR = 8_000.0


def _engine(wl, n):
    db = wl.make_db()
    cl = classify(db, wl.TXNS)
    return Engine(db, wl.TXNS, cl, EngineSpec(n_servers=n)), cl


def table1_classification() -> list[dict]:
    """Paper Table 1: classes + workload frequencies."""
    rows = []
    for name, wl, sampler in (
        ("tpcw", tpcw, lambda: tpcw.sample_ops(4000, seed=0)),
        ("rubis", rubis, lambda: rubis.sample_ops(4000, seed=0)),
    ):
        eng, cl = _engine(wl, 4)
        counts = cl.counts()
        ops = sampler()
        freq = {"L": 0, "G": 0, "C": 0}
        names = [t.name for t in wl.TXNS]
        for op_name, params in ops:
            ti = names.index(op_name)
            pv = np.zeros((eng.spec.max_params,), np.int32)
            for i, pn in enumerate(eng.txns[ti].params):
                pv[i] = params[pn]
            _, is_global = eng.route_np(ti, pv)
            oc = cl.classes[op_name]
            freq["C" if oc.cls == "C" else ("G" if is_global else "L")] += 1
        total = sum(freq.values())
        rows.append({
            "bench": "table1", "app": name, **counts,
            "freq_L": freq["L"] / total, "freq_G": freq["G"] / total,
            "freq_C": freq["C"] / total,
        })
        print(f"table1_{name},0,L{counts['L']}/G{counts['G']}/C{counts['C']}/"
              f"LG{counts['LG']}|freqL={freq['L']/total:.2f}")
    return rows


def fig3_lan_scaling(servers=(1, 2, 4, 8, 13, 16)) -> list[dict]:
    """Paper Fig. 3: LAN peak throughput, Eliá (conveyor) vs MySQL Cluster
    (2PC), TPC-W + RUBiS."""
    rows = []
    for name, wl, sample in (
        ("tpcw", tpcw, lambda: tpcw.sample_ops(3000, seed=1)),
        ("rubis", rubis, lambda: rubis.sample_ops(3000, seed=1)),
    ):
        pool = sample()
        best = {"conveyor": 0.0, "twopc": 0.0}
        for n in servers:
            eng, _ = _engine(wl, n)
            src = op_source_from_workload(eng, pool, n)
            for proto in ("conveyor", "twopc"):
                th, res = peak_throughput(proto, src, n, client_grid=CLIENTS,
                                          duration_ms=DUR)
                best[proto] = max(best[proto], th)
                rows.append({
                    "bench": "fig3", "app": name, "protocol": proto,
                    "servers": n, "peak_throughput": th,
                    "mean_latency_ms": res.mean_latency_ms,
                })
        ratio = best["conveyor"] / max(best["twopc"], 1e-9)
        print(f"fig3_{name},_,conveyor/2pc_peak_ratio={ratio:.2f}x")
    return rows


def fig4_wan(servers=(2, 3, 5)) -> list[dict]:
    """Paper Fig. 4: WAN throughput/latency vs centralized + read-only."""
    rows = []
    for name, wl, sample in (
        ("tpcw", tpcw, lambda: tpcw.sample_ops(3000, seed=2)),
        ("rubis", rubis, lambda: rubis.sample_ops(3000, seed=2)),
    ):
        pool = sample()
        for n in servers:
            eng, _ = _engine(wl, n)
            src = op_source_from_workload(eng, pool, n)
            for proto in ("conveyor", "central", "readonly"):
                th, res = peak_throughput(proto, src, n, wan=True,
                                          client_grid=CLIENTS, duration_ms=DUR)
                rows.append({
                    "bench": "fig4", "app": name, "protocol": proto,
                    "servers": n, "peak_throughput": th,
                    "mean_latency_ms": res.mean_latency_ms,
                })
        conv = max(r["peak_throughput"] for r in rows
                   if r["bench"] == "fig4" and r["app"] == name
                   and r["protocol"] == "conveyor")
        cent = max(r["peak_throughput"] for r in rows
                   if r["bench"] == "fig4" and r["app"] == name
                   and r["protocol"] == "central")
        print(f"fig4_{name},_,conveyor/central_throughput={conv/max(cent,1e-9):.2f}x")
    return rows


def table3_latency(servers=(2, 3, 5)) -> list[dict]:
    """Paper Table 3: light-load WAN latency vs centralized."""
    rows = []
    for name, wl, sample in (
        ("tpcw", tpcw, lambda: tpcw.sample_ops(3000, seed=3)),
        ("rubis", rubis, lambda: rubis.sample_ops(3000, seed=3)),
    ):
        pool = sample()
        eng, _ = _engine(wl, 1)
        src1 = op_source_from_workload(eng, pool, 1)
        cent = simulate("central", src1, 1, 8, duration_ms=DUR, wan=True)
        rows.append({"bench": "table3", "app": name, "config": "centralized",
                     "mean_latency_ms": cent.mean_latency_ms})
        for n in servers:
            eng, _ = _engine(wl, n)
            src = op_source_from_workload(eng, pool, n)
            for proto in ("conveyor", "readonly"):
                res = simulate(proto, src, n, 8, duration_ms=DUR, wan=True)
                rows.append({
                    "bench": "table3", "app": name,
                    "config": f"{proto}-{n}",
                    "mean_latency_ms": res.mean_latency_ms,
                    "speedup_vs_central":
                        cent.mean_latency_ms / max(res.mean_latency_ms, 1e-9),
                })
        best = max(r.get("speedup_vs_central", 0) for r in rows
                   if r["bench"] == "table3" and r["app"] == name)
        print(f"table3_{name},{cent.mean_latency_ms*1e3:.0f},"
              f"best_latency_speedup={best:.1f}x")
    return rows


def fig5_local_ratio(ratios=(0.0, 0.3, 0.5, 0.7, 0.9)) -> list[dict]:
    """Paper Figs. 5–6: sensitivity to the local-op fraction (3-server WAN,
    5 ms op execution, exactly the paper's micro-benchmark)."""
    rows = []
    for ratio in ratios:
        eng, _ = _engine(micro, 3)
        src = op_source_from_workload(
            eng, micro.sample_ops(3000, local_ratio=ratio, seed=4), 3
        )
        th, _ = peak_throughput("conveyor", src, 3, wan=True,
                                client_grid=CLIENTS, duration_ms=DUR)
        light = simulate("conveyor", src, 3, 8, duration_ms=DUR, wan=True)
        rows.append({
            "bench": "fig5", "local_ratio": ratio, "peak_throughput": th,
            "mean_latency_ms": light.mean_latency_ms,
            "mean_local_ms": light.mean_local_ms,
            "mean_global_ms": light.mean_global_ms,
        })
        print(f"fig5_ratio{ratio:.1f},{light.mean_latency_ms*1e3:.0f},"
              f"peak={th:.0f}ops/s|local={light.mean_local_ms:.0f}ms|"
              f"global={light.mean_global_ms:.0f}ms")
    return rows
