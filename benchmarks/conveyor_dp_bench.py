"""Conveyor-DP vs synchronous all-reduce (the framework-level realization of
the paper's Eliá-vs-MySQL-Cluster comparison).

On this CPU host we measure: (a) wall time per step for R=2 replicas under
belt sync vs a single sync step at 2× batch (same total tokens), (b) wire
bytes (int8 belt vs bf16 all-reduce equivalent), (c) loss parity after N
steps."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLM
from repro.launch.conveyor_dp import ConveyorDP
from repro.launch.steps import make_train_step
from repro.launch.train import scaled_config
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.optim.adamw import adamw_init


def run(steps=20, arch="qwen3-1.7b", scale=0.04, seq=64, batch=4) -> list[dict]:
    cfg = scaled_config(arch, scale, seq)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    step_fn = jax.jit(make_train_step(model, opt_cfg, total_steps=steps))
    ds = SyntheticLM(cfg.vocab, seq, batch)

    # sync baseline: one step over 2× batch
    ds2 = SyntheticLM(cfg.vocab, seq, 2 * batch)
    p_sync, o_sync = params, adamw_init(params)
    b0 = {k: jnp.asarray(v) for k, v in ds2.batch(0).items()}
    p_sync, o_sync, _ = step_fn(p_sync, o_sync, b0)  # warm
    t0 = time.time()
    losses_sync = []
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in ds2.batch(s).items()}
        p_sync, o_sync, m = step_fn(p_sync, o_sync, b)
        losses_sync.append(float(m["loss"]))
    t_sync = (time.time() - t0) / steps

    # belt: 2 replicas, half batch each, int8 deltas
    belt = ConveyorDP(step_fn, [params] * 2,
                      [adamw_init(params) for _ in range(2)])
    batches0 = [{k: jnp.asarray(v) for k, v in ds.batch(0).items()}] * 2
    belt.round(batches0)  # warm
    t0 = time.time()
    losses_belt = []
    for s in range(steps):
        bs = [{k: jnp.asarray(v) for k, v in ds.batch(2 * s + r).items()}
              for r in range(2)]
        ms = belt.round(bs)
        losses_belt.append(np.mean([m["loss"] for m in ms]))
    t_belt = (time.time() - t0) / steps
    belt.drain()

    param_bytes = sum(x.size * 2 for x in jax.tree.leaves(params))
    # ring all-reduce moves 2(R-1)/R × bytes per step (bf16)
    allreduce_wire = 2 * (2 - 1) / 2 * param_bytes * 4  # f32 grads
    belt_wire = belt.stats.bytes_shipped / belt.stats.rounds
    print(f"conveyor_dp_step,{t_belt*1e6:.0f},"
          f"sync_step_us={t_sync*1e6:.0f}|wire_ratio="
          f"{allreduce_wire/max(belt_wire,1):.1f}x|"
          f"loss_belt={losses_belt[-1]:.3f}|loss_sync={losses_sync[-1]:.3f}")
    return [{
        "bench": "conveyor_dp",
        "t_belt_us": t_belt * 1e6,
        "t_sync_us": t_sync * 1e6,
        "belt_wire_bytes_per_round": belt_wire,
        "allreduce_wire_bytes_per_step": allreduce_wire,
        "final_loss_belt": losses_belt[-1],
        "final_loss_sync": losses_sync[-1],
    }]
