"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(step, *, warmup: int = 100, total: int = 10_000,
                  min_ratio: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    step = step + 1.0  # first optimizer step uses lr > 0
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
