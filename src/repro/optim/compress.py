"""Int8 gradient/delta compression with error feedback.

Used by the Conveyor-DP sync mode: parameter deltas circulating on the token
ring are quantized to int8 with a per-tensor fp32 scale; the quantization
residual is fed back into the next round's delta (error feedback keeps the
long-run update unbiased).  4× less ICI traffic on the belt.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(tree, error=None):
    """tree → (int8 tree, scales tree, new error tree)."""
    if error is None:
        error = jax.tree.map(lambda t: jnp.zeros_like(t, jnp.float32), tree)

    def one(t, e):
        t32 = t.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(t32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(t32 / scale), -127, 127).astype(jnp.int8)
        new_e = t32 - q.astype(jnp.float32) * scale
        return q, scale, new_e

    flat, tdef = jax.tree.flatten(tree)
    flat_e = tdef.flatten_up_to(error)
    out = [one(t, e) for t, e in zip(flat, flat_e)]
    return (
        tdef.unflatten([o[0] for o in out]),
        tdef.unflatten([o[1] for o in out]),
        tdef.unflatten([o[2] for o in out]),
    )


def int8_decompress(q_tree, scales, dtype=jnp.float32):
    return jax.tree.map(
        lambda q, s: (q.astype(jnp.float32) * s).astype(dtype), q_tree, scales
    )
