from .adamw import (  # noqa: F401
    AdamWConfig,
    adamw_init,
    adamw_state_specs,
    adamw_update,
    clip_by_global_norm,
)
from .compress import int8_compress, int8_decompress  # noqa: F401
from .schedule import cosine_warmup  # noqa: F401
