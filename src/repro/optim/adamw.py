"""AdamW with ZeRO-style sharded state.

Moments are fp32 and inherit each parameter's PartitionSpec — with FSDP
configs the full optimizer state is already fully sharded over
(pod, data, model); there is no replicated master copy (bf16 params +
fp32 moments; the update is computed in fp32 and cast back), which is what
lets the 1T MoE fit 16 GB/chip (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer HBM (1T MoE)


def adamw_init(params, moment_dtype: str = "float32"):
    dt = jnp.bfloat16 if moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_state_specs(param_specs):
    """Moment specs mirror parameter specs; step is replicated."""
    from jax.sharding import PartitionSpec as P

    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
