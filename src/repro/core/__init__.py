"""Operation Partitioning + Conveyor Belt (Saissi et al. 2018) — core.

Public surface:
  state:      Database, TableSchema, DbState
  rwsets:     Transaction, extract_rwsets, execute_txn
  partition:  optimize_partitioning (Algorithm 1), detect_conflicts
  classify:   classify, Classification, OpClass
  conveyor:   Engine, EngineSpec, VirtualBelt (+ spmd deployment in spmd.py)
  serial:     run_workload, check_serializable, total_order
"""
from .classify import Classification, OpClass, classify  # noqa: F401
from .conveyor import Batch, Engine, EngineSpec, VirtualBelt  # noqa: F401
from .partition import detect_conflicts, optimize_partitioning  # noqa: F401
from .rwsets import Transaction, execute_txn, extract_rwsets  # noqa: F401
from .serial import check_serializable, run_workload, total_order  # noqa: F401
from .state import Database, DbState, TableSchema  # noqa: F401
