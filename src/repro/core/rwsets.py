"""Static read/write-set extraction (paper §3.1, "Extracting read/write sets").

The paper parses SQL inside transaction bodies.  Our transactions are Python
functions written against a ``TxView`` effect API; static analysis runs the
body once under a ``TraceView`` whose reads return opaque symbolic values and
whose effects are recorded as ⟨accessed-attributes, condition⟩ entries —
exactly the paper's pessimistic, path-insensitive extraction ("all SQL
statements ... regardless of the execution path").

A condition is a conjunction of atoms ``table.key_attr = binding`` where the
binding is a transaction input parameter, a constant, or ⊥ (value-dependent
addressing, e.g. a key obtained from a previous read — conservatively matches
any row, as in the paper's static over-approximation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax.numpy as jnp

from .state import Database, DbState, TableSchema

# A binding is ("param", name) | ("const", value) | None (unbound / ⊥).
Binding = tuple | None


@dataclasses.dataclass(frozen=True)
class Atom:
    table: str
    key_attr: str
    binding: Binding


@dataclasses.dataclass(frozen=True)
class Entry:
    """One ⟨A, C⟩ read- or write-set entry (paper §3.1)."""

    attrs: frozenset  # of (table, attr)
    cond: tuple  # of Atom

    def bindings_for(self, table: str) -> dict:
        return {a.key_attr: a.binding for a in self.cond if a.table == table}


@dataclasses.dataclass(frozen=True)
class RWSets:
    reads: tuple  # of Entry
    writes: tuple  # of Entry


@dataclasses.dataclass(frozen=True)
class Transaction:
    """A stored-procedure-style transaction (paper §3: "transactions are
    procedures having a certain number of input parameters")."""

    name: str
    params: tuple[str, ...]
    body: Callable  # body(view, p: dict[str, value]) -> reply (int-like) | None
    weight: float = 1.0
    # Upper bound on rows written in one execution (sizes the update records
    # shipped on the token; checked at trace time).
    max_writes: int = 4


class SymValue:
    """Opaque value flowing out of symbolic reads; supports arithmetic so the
    same transaction body runs under trace and execution."""

    __slots__ = ()

    def _op(self, *_):
        return SymValue()

    __add__ = __radd__ = __sub__ = __rsub__ = __mul__ = __rmul__ = _op
    __neg__ = __mod__ = __floordiv__ = _op
    __lt__ = __le__ = __gt__ = __ge__ = _op

    def __eq__(self, other):  # type: ignore[override]
        return SymValue()

    def __hash__(self):
        return 0


def _binding_of(x) -> Binding:
    if isinstance(x, _ParamRef):
        return ("param", x.name)
    if isinstance(x, (int, bool)):
        return ("const", int(x))
    return None  # SymValue / traced value → unbound


@dataclasses.dataclass(frozen=True)
class _ParamRef:
    name: str

    # Parameters may be combined arithmetically; the result is no longer a
    # pure parameter binding (conservative ⊥), but remains usable as a value.
    def _op(self, *_):
        return SymValue()

    __add__ = __radd__ = __sub__ = __rsub__ = __mul__ = __rmul__ = _op
    __neg__ = __mod__ = __floordiv__ = _op


class TxView:
    """Interface shared by TraceView (static analysis) and ExecView."""

    def read(self, table: str, attr: str, key: Sequence) -> Any:
        raise NotImplementedError

    def write(self, table: str, attr: str, key: Sequence, value) -> None:
        raise NotImplementedError

    def add(self, table: str, attr: str, key: Sequence, value) -> None:
        raise NotImplementedError

    def where(self, cond, a, b):
        raise NotImplementedError


class TraceView(TxView):
    def __init__(self, db: Database):
        self.db = db
        self.reads: list[Entry] = []
        self.writes: list[Entry] = []
        self.n_writes = 0

    def _cond(self, schema: TableSchema, key: Sequence) -> tuple:
        assert len(key) == len(schema.key_attrs), (schema.name, key)
        return tuple(
            Atom(schema.name, ka, _binding_of(k))
            for ka, k in zip(schema.key_attrs, key)
        )

    def read(self, table, attr, key):
        schema = self.db.table(table)
        self.reads.append(
            Entry(frozenset({(table, attr)}), self._cond(schema, key))
        )
        return SymValue()

    def write(self, table, attr, key, value):
        schema = self.db.table(table)
        self.writes.append(
            Entry(frozenset({(table, attr)}), self._cond(schema, key))
        )
        self.n_writes += 1

    def add(self, table, attr, key, value):
        # read-modify-write: contributes to both sets (paper: UPDATE with
        # arithmetic reads the old value).
        schema = self.db.table(table)
        cond = self._cond(schema, key)
        self.reads.append(Entry(frozenset({(table, attr)}), cond))
        self.writes.append(Entry(frozenset({(table, attr)}), cond))
        self.n_writes += 1

    def where(self, cond, a, b):
        return SymValue()


def extract_rwsets(db: Database, txn: Transaction) -> RWSets:
    view = TraceView(db)
    params = {p: _ParamRef(p) for p in txn.params}
    txn.body(view, params)
    assert view.n_writes <= txn.max_writes, (
        f"{txn.name}: traced {view.n_writes} writes > max_writes={txn.max_writes}"
    )
    return RWSets(tuple(view.reads), tuple(view.writes))


# ---------------------------------------------------------------------------
# Concrete execution + passive-replication update recording (paper §5,
# "Extracting state updates": the after-image of every mutated row).
# ---------------------------------------------------------------------------


class ExecView(TxView):
    """Executes a transaction body against a DbState, recording full-row
    after-images of every write — the paper's "state update" u."""

    def __init__(self, db: Database, state: DbState):
        self.db = db
        self.state = state
        self.updates: list[tuple[int, Any, Any]] = []  # (table_id, row, row_vals)

    def _record(self, table: str, key):
        schema = self.db.table(table)
        row = schema.flat_key(key)
        self.updates.append(
            (self.db.table_id(table), row, self.state.read_row(schema, key))
        )

    def read(self, table, attr, key):
        return self.state.read(self.db.table(table), attr, key)

    def write(self, table, attr, key, value):
        self.state = self.state.write(self.db.table(table), attr, key, value)
        self._record(table, key)

    def add(self, table, attr, key, value):
        self.state = self.state.add(self.db.table(table), attr, key, value)
        self._record(table, key)

    def where(self, cond, a, b):
        return jnp.where(cond, a, b)


def execute_txn(
    db: Database, state: DbState, txn: Transaction, params: dict
) -> tuple[DbState, Any, list]:
    """Run one transaction; returns (new_state, reply, update_records)."""
    view = ExecView(db, state)
    reply = txn.body(view, params)
    if reply is None:
        reply = jnp.int32(0)
    return view.state, jnp.asarray(reply, jnp.int32), view.updates
