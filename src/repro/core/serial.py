"""Serializability oracle + checker (paper Theorem 1 / Appendix).

``run_workload`` drives a belt over a concrete operation stream (host-side
routing, exactly the paper's client → owning-server dispatch with MAP
redirects folded in).  ``check_serializable`` reconstructs the total order T
from the execution stamps — global operations ordered by their token sequence
number, local/commutative operations slotted between the global updates they
had observed (the B_p^l / A_p^l construction of the proof) — replays it on a
single-server oracle, and asserts reply and state equivalence.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .conveyor import Batch, Engine, VirtualBelt
from .rwsets import execute_txn
from .state import Database, DbState


@dataclasses.dataclass
class OpResult:
    op_id: int
    txn: str
    params: dict
    reply: int
    is_global: bool
    order_key: int
    server: int
    seq: int
    round: int


def make_batches(engine: Engine, ops: list, round_idx: int) -> Batch:
    """Route concrete ops to per-server padded batches (client-side MAP)."""
    s = engine.spec
    n, b, p = s.n_servers, s.batch, s.max_params
    op_type = np.zeros((n, b), np.int32)
    params = np.zeros((n, b, p), np.int32)
    op_id = np.full((n, b), -1, np.int32)
    valid = np.zeros((n, b), bool)
    fill = np.zeros((n,), np.int32)
    leftover = []
    for oid, tname, pdict in ops:
        ti = [t.name for t in engine.txns].index(tname)
        txn = engine.txns[ti]
        pv = np.zeros((p,), np.int32)
        for i, name in enumerate(txn.params):
            pv[i] = pdict[name]
        server, _ = engine.route_np(ti, pv)
        if fill[server] >= b:
            leftover.append((oid, tname, pdict))
            continue
        k = fill[server]
        op_type[server, k] = ti
        params[server, k] = pv
        op_id[server, k] = oid
        valid[server, k] = True
        fill[server] += 1
    batch = Batch(
        jnp.asarray(op_type), jnp.asarray(params), jnp.asarray(op_id),
        jnp.asarray(valid)
    )
    return batch, leftover


def run_workload(
    engine: Engine, init_state: DbState, ops: Sequence[tuple[str, dict]],
    ops_per_round: int | None = None,
) -> tuple[VirtualBelt, list[OpResult]]:
    """Execute ops on a VirtualBelt; returns the drained belt + results."""
    belt = VirtualBelt(engine, init_state)
    n = engine.spec.n_servers
    per_round = ops_per_round or engine.spec.batch * n // 2 or 1
    pending = [(i, t, p) for i, (t, p) in enumerate(ops)]
    results: dict[int, OpResult] = {}

    def collect(recs, round_idx, nested):
        r = jax.tree.map(np.asarray, recs)
        it = (
            np.ndindex(r.op_id.shape) if nested else
            ((i,) for i in range(r.op_id.shape[0]))
        )
        for idx in it:
            if r.valid[idx] and r.op_id[idx] >= 0:
                oid = int(r.op_id[idx])
                results[oid] = OpResult(
                    oid, ops[oid][0], ops[oid][1], int(r.reply[idx]),
                    bool(r.is_global[idx]), int(r.order_key[idx]),
                    int(r.server[idx]), int(r.seq[idx]), round_idx,
                )

    round_idx = 0
    while pending or round_idx == 0:
        take, rest = pending[:per_round], pending[per_round:]
        batch, leftover = make_batches(engine, take, round_idx)
        pending = leftover + rest
        a, b = belt.run_round(batch)
        collect(a, round_idx, nested=True)
        collect(b, round_idx, nested=False)
        round_idx += 1
        assert round_idx < 10_000, "workload did not drain"
    # Drain: N extra empty rounds so every queued global executes and every
    # update completes a full token circulation.
    empty = [(None)] * 0
    for _ in range(2 * n + 2):
        batch, _ = make_batches(engine, empty, round_idx)
        a, b = belt.run_round(batch)
        collect(a, round_idx, nested=True)
        collect(b, round_idx, nested=False)
        round_idx += 1
    assert not bool(np.asarray(belt.token.overflow)), "token overflow"
    missing = [i for i in range(len(ops)) if i not in results]
    assert not missing, f"ops never executed: {missing[:5]}"
    return belt, [results[i] for i in range(len(ops))]


def total_order(results: Sequence[OpResult]) -> list[OpResult]:
    """The serialization T from the correctness proof."""
    return sorted(
        results,
        key=lambda r: (
            r.order_key,
            0 if r.is_global else 1,
            r.server,
            r.round,
            r.seq,
        ),
    )


def check_serializable(
    db: Database,
    engine: Engine,
    init_state: DbState,
    belt: VirtualBelt,
    results: Sequence[OpResult],
) -> None:
    """Replay T on a single server; assert replies + state equivalence."""
    order = total_order(results)
    txn_by_name = {t.name: t for t in engine.txns}
    state = init_state
    # last writer per (table, row): ('G', -1) global or ('L', server)
    last_writer: dict[tuple[str, int], tuple[str, int]] = {}
    for r in order:
        txn = txn_by_name[r.txn]
        state, reply, ups = execute_txn(db, state, txn, dict(r.params))
        assert int(reply) == r.reply, (
            f"reply mismatch for {r.txn}{r.params}: oracle {int(reply)} "
            f"vs belt {r.reply} (op {r.op_id})"
        )
        for tid, row, _ in ups:
            tname = db.tables[tid].name
            last_writer[(tname, int(row))] = (
                ("G", -1) if r.is_global else ("L", r.server)
            )
    # State equivalence: rows written by globals must match the oracle at
    # EVERY server (replication); rows written by locals must match at the
    # owner.  write_only (log) tables are excluded (never read; the paper's
    # commutative-writes argument).
    oracle = jax.tree.map(np.asarray, state)
    for (tname, row), (kind, owner) in last_writer.items():
        schema = db.table(tname)
        if schema.write_only:
            continue
        want = oracle.arrays[tname][row]
        servers = (
            range(engine.spec.n_servers) if kind == "G" else [owner]
        )
        for p in servers:
            got = np.asarray(belt.server_state(p).arrays[tname][row])
            assert np.array_equal(got, want), (
                f"state divergence {tname}[{row}] at server {p}: "
                f"{got} vs oracle {want} (last writer {kind}{owner})"
            )
