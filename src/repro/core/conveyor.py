"""The Conveyor Belt protocol (paper §4, Algorithm 2) in JAX.

Execution model
---------------
Time is divided into *rounds*.  In every round each server (a) executes the
commutative / local operations of its incoming batch immediately and buffers
global operations into its bounded queue Q (Algorithm 2 lines 1–9), and (b)
the single token holder applies remote state updates carried by the token,
removes its own (everyone has seen them), atomically snapshots its queue,
executes the snapshot as a batch, appends the resulting state updates, and
passes the token (lines 10–22).  The token advances one hop per round.

Two interchangeable realizations share the per-server phase functions below:

* ``VirtualBelt`` — single-device, explicit leading server axis, token hop is
  an index rotation.  Used by unit/property tests and the serializability
  checker.
* ``spmd.py`` — `jax.shard_map` over a mesh axis, token hop is
  ``lax.ppermute`` (the only collective in the protocol — it is lock-free:
  no server ever blocks another's local operations).

State updates are full-row after-images (passive replication, paper §5), so
``apply`` never re-executes remote operations.

Order stamps: every executed op is stamped with (is_global, gseq-or-applied,
server, seq) from which ``serial.py`` reconstructs the equivalent total order
T of the correctness proof (global ops by token sequence number; local ops
between the global updates they observed — the B_p^l / A_p^l sets).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .classify import Classification, COMMUTATIVE, DUAL, GLOBAL, LOCAL
from .rwsets import Transaction, execute_txn
from .state import Database, DbState

CLS_CODE = {COMMUTATIVE: 0, LOCAL: 1, GLOBAL: 2, DUAL: 3}


class Queue(NamedTuple):
    op_type: jax.Array  # (Q,) int32
    params: jax.Array  # (Q, P) int32
    op_id: jax.Array  # (Q,) int32
    n: jax.Array  # () int32


class Token(NamedTuple):
    table: jax.Array  # (T,) int32
    row: jax.Array  # (T,) int32
    vals: jax.Array  # (T, A) int32
    origin: jax.Array  # (T,) int32
    gseq: jax.Array  # (T,) int32
    valid: jax.Array  # (T,) bool
    next_gseq: jax.Array  # () int32
    overflow: jax.Array  # () bool — capacity violation flag (checked by tests)


class Batch(NamedTuple):
    """Ops routed to one server for one round (padded)."""

    op_type: jax.Array  # (B,) int32
    params: jax.Array  # (B, P) int32
    op_id: jax.Array  # (B,) int32
    valid: jax.Array  # (B,) bool


class ExecRecord(NamedTuple):
    """Per-op outputs for reply collection and order reconstruction."""

    op_id: jax.Array
    reply: jax.Array
    is_global: jax.Array
    order_key: jax.Array  # gseq for globals; applied_gseq at exec for locals
    server: jax.Array
    seq: jax.Array
    valid: jax.Array


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    n_servers: int
    batch: int = 8
    queue_cap: int = 64
    token_cap: int = 256
    max_params: int = 4


class Engine:
    """Compiles an application (db schema + transactions + classification)
    into jittable per-server phase functions."""

    def __init__(
        self,
        db: Database,
        txns: Sequence[Transaction],
        classification: Classification,
        spec: EngineSpec,
    ):
        self.db = db
        self.txns = list(txns)
        self.classification = classification
        self.spec = spec
        self.max_attrs = db.max_attrs
        self.max_writes = max(t.max_writes for t in txns)

        n = len(txns)
        cls_code = np.zeros((n,), np.int32)
        prim_idx = np.full((n,), -1, np.int32)
        sec_idx = np.full((n,), -1, np.int32)
        for i, t in enumerate(txns):
            oc = classification.classes[t.name]
            cls_code[i] = CLS_CODE[oc.cls]
            if oc.primary is not None:
                prim_idx[i] = t.params.index(oc.primary)
            if oc.secondary is not None:
                sec_idx[i] = t.params.index(oc.secondary)
        self.cls_code = jnp.asarray(cls_code)
        self.prim_idx = jnp.asarray(prim_idx)
        self.sec_idx = jnp.asarray(sec_idx)
        self._np_cls = cls_code
        self._np_prim = prim_idx
        self._np_sec = sec_idx

    # -- routing (deterministic, shared by host driver and jitted code) -----
    def route_np(self, op_type: int, params: np.ndarray) -> tuple[int, bool]:
        n = self.spec.n_servers
        cls = int(self._np_cls[op_type])
        pi = int(self._np_prim[op_type])
        if cls == 0:  # commutative: load-balance hash (uint32 wraparound,
            # identical in route_jax)
            h = (int(params.astype(np.int64).sum()) * 1000003) & 0xFFFFFFFF
            return h % n, False
        server = int(params[pi]) % n if pi >= 0 else 0
        if cls == 1:
            return server, False
        if cls == 3:
            si = int(self._np_sec[op_type])
            s2 = int(params[si]) % n
            return server, server != s2
        return server, True

    def route_jax(self, op_type, params):
        n = self.spec.n_servers
        cls = self.cls_code[op_type]
        pi = self.prim_idx[op_type]
        prim = jnp.where(pi >= 0, params[jnp.maximum(pi, 0)], 0)
        comm_server = (
            (params.astype(jnp.uint32).sum() * jnp.uint32(1000003)) % jnp.uint32(n)
        ).astype(jnp.int32)
        server = jnp.where(cls == 0, comm_server, prim.astype(jnp.int32) % n)
        si = self.sec_idx[op_type]
        sec = jnp.where(si >= 0, params[jnp.maximum(si, 0)], 0).astype(jnp.int32) % n
        is_global = jnp.where(
            cls == 2, True, jnp.where(cls == 3, server != sec, False)
        )
        return server, is_global

    # -- single-op execution via lax.switch ---------------------------------
    def exec_op(self, state: DbState, op_type, params):
        """(state', reply, updates) — updates padded to max_writes records of
        (table_id, row, vals[max_attrs], valid)."""

        def make_branch(txn: Transaction):
            def branch(state_params):
                state, params = state_params
                p = {name: params[i] for i, name in enumerate(txn.params)}
                new_state, reply, ups = execute_txn(self.db, state, txn, p)
                tb = jnp.full((self.max_writes,), -1, jnp.int32)
                rw = jnp.zeros((self.max_writes,), jnp.int32)
                vl = jnp.zeros((self.max_writes, self.max_attrs), jnp.int32)
                ok = jnp.zeros((self.max_writes,), bool)
                for j, (tid, row, vals) in enumerate(ups[: self.max_writes]):
                    tb = tb.at[j].set(tid)
                    rw = rw.at[j].set(row)
                    vl = vl.at[j, : vals.shape[0]].set(vals)
                    ok = ok.at[j].set(True)
                return new_state, reply, (tb, rw, vl, ok)

            return branch

        return jax.lax.switch(
            op_type, [make_branch(t) for t in self.txns], (state, params)
        )

    # -- Phase A: immediate execution of commutative/local ops --------------
    def phase_a(self, state: DbState, queue: Queue, applied_gseq, batch: Batch,
                server_idx):
        """One server, one round: Algorithm 2 lines 1–9 over the batch."""

        def step(carry, slot):
            state, queue = carry
            op_type, params, op_id, valid = slot
            _, is_global = self.route_jax(op_type, params)
            run_now = valid & ~is_global
            new_state, reply, _ = self.exec_op(state, op_type, params)
            state = new_state.select(run_now, state)
            # enqueue global ops (bounded queue; overflow drops + flags)
            enq = valid & is_global
            pos = jnp.minimum(queue.n, self.spec.queue_cap - 1)
            queue = Queue(
                op_type=jnp.where(
                    enq, queue.op_type.at[pos].set(op_type), queue.op_type
                ),
                params=jnp.where(
                    enq, queue.params.at[pos].set(params), queue.params
                ),
                op_id=jnp.where(enq, queue.op_id.at[pos].set(op_id), queue.op_id),
                n=queue.n + jnp.where(enq, 1, 0),
            )
            rec = ExecRecord(
                op_id=op_id,
                reply=jnp.where(run_now, reply, 0),
                is_global=jnp.zeros((), bool),
                order_key=applied_gseq,
                server=jnp.asarray(server_idx, jnp.int32),
                seq=jnp.zeros((), jnp.int32),
                valid=run_now,
            )
            return (state, queue), rec

        (state, queue), recs = jax.lax.scan(
            step, (state, queue), (batch.op_type, batch.params, batch.op_id,
                                   batch.valid)
        )
        recs = recs._replace(seq=jnp.arange(self.spec.batch, dtype=jnp.int32))
        return state, queue, recs

    # -- Phase B: token receipt (Algorithm 2 lines 10–22) -------------------
    def phase_b(self, state: DbState, queue: Queue, token: Token, server_idx):
        sid = jnp.asarray(server_idx, jnp.int32)

        # 1. apply remote updates; remove own (all servers have seen them).
        def apply_step(st, rec):
            tb, row, vals, origin, gq, valid = rec
            do = valid & (origin != sid)
            new = st
            for t_i, schema in enumerate(self.db.tables):
                hit = do & (tb == t_i)
                nvals = vals[: len(schema.attrs)]
                upd = DbState(
                    {
                        **st.arrays,
                        schema.name: st.arrays[schema.name]
                        .at[row % schema.capacity]
                        .set(nvals),
                    }
                )
                new = upd.select(hit, new)
            applied = jnp.where(do, gq, -1)
            return new, applied

        state, applied_gqs = jax.lax.scan(
            apply_step,
            state,
            (token.table, token.row, token.vals, token.origin, token.gseq,
             token.valid),
        )
        keep = token.valid & (token.origin != sid)

        # 2. compact surviving records to the front (stable), then execute the
        #    queue snapshot and append new after-images.
        order = jnp.argsort(jnp.where(keep, 0, 1), stable=True)
        tok = Token(
            table=token.table[order],
            row=token.row[order],
            vals=token.vals[order],
            origin=token.origin[order],
            gseq=token.gseq[order],
            valid=keep[order],
            next_gseq=token.next_gseq,
            overflow=token.overflow,
        )
        n_kept = keep.sum(dtype=jnp.int32)

        def exec_step(carry, slot):
            state, tok, n_slots, n_exec = carry
            op_type, params, op_id = slot
            do = n_exec < queue.n
            new_state, reply, (tb, rw, vl, ok) = self.exec_op(
                state, op_type, params
            )
            state = new_state.select(do, state)
            gq = tok.next_gseq
            table_a, row_a, vals_a = tok.table, tok.row, tok.vals
            origin_a, gseq_a, valid_a = tok.origin, tok.gseq, tok.valid
            overflow = tok.overflow
            for j in range(self.max_writes):
                put = do & ok[j]
                pos = jnp.minimum(n_slots, self.spec.token_cap - 1)
                overflow = overflow | (put & (n_slots >= self.spec.token_cap))
                table_a = jnp.where(put, table_a.at[pos].set(tb[j]), table_a)
                row_a = jnp.where(put, row_a.at[pos].set(rw[j]), row_a)
                vals_a = jnp.where(put, vals_a.at[pos].set(vl[j]), vals_a)
                origin_a = jnp.where(put, origin_a.at[pos].set(sid), origin_a)
                gseq_a = jnp.where(put, gseq_a.at[pos].set(gq), gseq_a)
                valid_a = jnp.where(put, valid_a.at[pos].set(True), valid_a)
                n_slots = n_slots + jnp.where(put, 1, 0)
            tok = Token(table_a, row_a, vals_a, origin_a, gseq_a, valid_a,
                        gq + jnp.where(do, 1, 0), overflow)
            rec = ExecRecord(
                op_id=op_id,
                reply=jnp.where(do, reply, 0),
                is_global=jnp.ones((), bool),
                order_key=gq,
                server=sid,
                seq=jnp.zeros((), jnp.int32),
                valid=do,
            )
            return (state, tok, n_slots, n_exec + jnp.where(do, 1, 0)), rec

        (state, tok, _, _), recs = jax.lax.scan(
            exec_step,
            (state, tok, n_kept, jnp.zeros((), jnp.int32)),
            (queue.op_type, queue.params, queue.op_id),
        )
        queue = Queue(
            op_type=queue.op_type,
            params=queue.params,
            op_id=queue.op_id,
            n=jnp.zeros((), jnp.int32),
        )
        new_applied = jnp.maximum(applied_gqs.max(), tok.next_gseq - 1)
        return state, queue, tok, recs, new_applied

    # -- empties -------------------------------------------------------------
    def empty_queue(self) -> Queue:
        s = self.spec
        return Queue(
            op_type=jnp.zeros((s.queue_cap,), jnp.int32),
            params=jnp.zeros((s.queue_cap, s.max_params), jnp.int32),
            op_id=jnp.full((s.queue_cap,), -1, jnp.int32),
            n=jnp.zeros((), jnp.int32),
        )

    def empty_token(self) -> Token:
        s = self.spec
        return Token(
            table=jnp.full((s.token_cap,), -1, jnp.int32),
            row=jnp.zeros((s.token_cap,), jnp.int32),
            vals=jnp.zeros((s.token_cap, self.max_attrs), jnp.int32),
            origin=jnp.full((s.token_cap,), -1, jnp.int32),
            gseq=jnp.full((s.token_cap,), -1, jnp.int32),
            valid=jnp.zeros((s.token_cap,), bool),
            next_gseq=jnp.zeros((), jnp.int32),
            overflow=jnp.zeros((), bool),
        )


class VirtualBelt:
    """Single-device belt: all N servers simulated with a leading axis.

    Semantically identical to the SPMD deployment (tests assert this); the
    token hop is an index rotation instead of a ppermute.
    """

    def __init__(self, engine: Engine, init_state: DbState):
        self.engine = engine
        n = engine.spec.n_servers
        self.dbs = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), init_state
        )
        self.queues = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape), engine.empty_queue()
        )
        self.token = engine.empty_token()
        # highest global seq whose update is reflected locally; -1 = none
        self.applied = jnp.full((n,), -1, jnp.int32)
        self.round = 0
        self._step = jax.jit(self._round_fn)

    def _round_fn(self, dbs, queues, token, applied, round_idx, batches: Batch):
        eng = self.engine
        n = eng.spec.n_servers
        sidx = jnp.arange(n, dtype=jnp.int32)

        dbs, queues, a_recs = jax.vmap(
            lambda db, q, ag, b, s: eng.phase_a(db, q, ag, b, s)
        )(dbs, queues, applied, batches, sidx)

        holder = jnp.asarray(round_idx % n, jnp.int32)
        tok_bcast = jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape),
                                 token)
        db_b, q_b, tok_b, b_recs, new_applied = jax.vmap(
            lambda db, q, t, s: eng.phase_b(db, q, t, s)
        )(dbs, queues, tok_bcast, sidx)

        is_h = sidx == holder
        dbs = jax.tree.map(
            lambda new, old: jnp.where(
                is_h.reshape((n,) + (1,) * (new.ndim - 1)), new, old
            ),
            db_b,
            dbs,
        )
        queues = jax.tree.map(
            lambda new, old: jnp.where(
                is_h.reshape((n,) + (1,) * (new.ndim - 1)), new, old
            ),
            q_b,
            queues,
        )
        token = jax.tree.map(lambda a: a[holder], tok_b)
        applied = jnp.where(is_h, jnp.maximum(new_applied, applied), applied)
        b_recs = jax.tree.map(lambda a: a[holder], b_recs)
        return dbs, queues, token, applied, a_recs, b_recs

    def run_round(self, batches: Batch):
        (self.dbs, self.queues, self.token, self.applied, a_recs, b_recs) = (
            self._step(self.dbs, self.queues, self.token, self.applied,
                       self.round, batches)
        )
        self.round += 1
        return jax.device_get(a_recs), jax.device_get(b_recs)

    def server_state(self, p: int) -> DbState:
        return jax.tree.map(lambda a: a[p], self.dbs)
