"""Operation classification (paper §3.2): commutative / local / global, plus
the runtime dual-key class used by RUBiS ("local/global" column of Table 1).

Definitions implemented verbatim:
  * commutative — no conflicts with any operation at all (immutable reads,
    never-read log writes);
  * local — partitioned; (i) no write-write conflict crosses partitions and
    (ii) no remote operation reads from it.  A local op MAY read from remote
    (global) operations — their updates are replicated by the belt;
  * global — everything else; still assigned to a partition (it may read
    local state only the owner has);
  * dual — has a secondary partitioning parameter covering all residual
    clauses: the concrete operation is local iff all its partitioning
    parameters route to the same server, global otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from .partition import (
    Conflict,
    find_dual_keys,
    optimize_partitioning,
    residual_clauses,
)
from .rwsets import RWSets, Transaction, extract_rwsets

COMMUTATIVE, LOCAL, GLOBAL, DUAL = "C", "L", "G", "LG"


@dataclasses.dataclass(frozen=True)
class OpClass:
    cls: str
    primary: str | None  # partitioning parameter (index into txn params)
    secondary: str | None = None


@dataclasses.dataclass(frozen=True)
class Classification:
    """Output of the full offline analysis; input to the Conveyor Belt."""

    P: Mapping[str, str | None]
    classes: Mapping[str, OpClass]
    conflicts: Sequence[Conflict]
    cost: float

    def counts(self) -> dict:
        out = {COMMUTATIVE: 0, LOCAL: 0, GLOBAL: 0, DUAL: 0}
        for oc in self.classes.values():
            out[oc.cls] += 1
        return out


def _violates_locality(name: str, cf: Conflict, P) -> bool:
    """True if some residual clause of `cf` breaks conditions (i)/(ii) for
    transaction `name` (cross-partition ww, or remote reader of our writes).
    Reading from a remote writer does NOT break our locality."""
    for c in residual_clauses(cf, P):
        if c.kind == "ww":
            return True
        # 'rf': cf.t reads from cf.t2  → breaks locality of the WRITER cf.t2
        # 'fr': cf.t2 reads from cf.t  → breaks locality of the WRITER cf.t
        writer = cf.t2 if c.kind == "rf" else cf.t
        if writer == name:
            return True
    return False


def classify(db, txns: Sequence[Transaction]) -> Classification:
    """Full offline pipeline: extract rw-sets → Algorithm 1 → classes."""
    rwsets: dict[str, RWSets] = {t.name: extract_rwsets(db, t) for t in txns}
    P, conflicts, best_cost = optimize_partitioning(db, txns, rwsets)
    secondary = find_dual_keys(txns, rwsets, conflicts, P)

    classes: dict[str, OpClass] = {}
    for t in txns:
        n = t.name
        involved = [cf for cf in conflicts if n in (cf.t, cf.t2)]
        if not involved:
            classes[n] = OpClass(COMMUTATIVE, None)
            continue
        if not any(_violates_locality(n, cf, P) for cf in involved):
            classes[n] = OpClass(LOCAL, P.get(n))
            continue
        if secondary.get(n) is not None:
            classes[n] = OpClass(DUAL, P.get(n), secondary[n])
            continue
        classes[n] = OpClass(GLOBAL, P.get(n))
    return Classification(P, classes, conflicts, best_cost)


# ---------------------------------------------------------------------------
# Routing (paper: "the same deterministic routing function for all
# operations").  Works on concrete parameter values (python ints or arrays).
# ---------------------------------------------------------------------------


def route(value, n_servers: int):
    return value % n_servers


def op_partition(
    txn: Transaction, oc: OpClass, params: Mapping[str, int], n_servers: int
):
    """(server, is_global) for a concrete operation.

    Commutative ops may run anywhere (we route by a cheap hash for load
    balance).  Dual ops are local iff all partitioning params co-route.
    """
    if oc.cls == COMMUTATIVE:
        h = 0
        for p in txn.params:
            h = (h * 1000003 + int(params[p])) & 0x7FFFFFFF
        return h % n_servers, False
    if oc.primary is None:
        return 0, oc.cls != LOCAL
    server = route(int(params[oc.primary]), n_servers)
    if oc.cls == LOCAL:
        return server, False
    if oc.cls == DUAL:
        assert oc.secondary is not None
        server2 = route(int(params[oc.secondary]), n_servers)
        return server, server != server2
    return server, True  # GLOBAL
