from . import micro, rubis, tpcw  # noqa: F401
