"""RUBiS analogue (paper §6): auction site with the paper's double-key
scheme — storeBid/buyNow are partitioned by BOTH user id and item id and are
local iff both route to the same server (Table 1 "L/G" class)."""
from __future__ import annotations

import numpy as np

from ..rwsets import Transaction
from ..state import Database, TableSchema

N_USERS, N_ITEMS = 64, 64


def make_db() -> Database:
    return Database(
        tables=(
            TableSchema("USERS", ("rating", "balance"), ("u_id",), (N_USERS,)),
            TableSchema(
                "AUCTIONS", ("max_bid", "n_bids", "seller"), ("i_id",), (N_ITEMS,)
            ),
            TableSchema("BIDS", ("amount",), ("u_id", "i_id"), (N_USERS, N_ITEMS)),
            TableSchema(
                "CATEGORIES", ("name_id",), ("cat_id",), (16,), immutable=True
            ),
            TableSchema("VIEW_LOG", ("hits",), ("slot",), (32,), write_only=True),
        )
    )


def view_profile(v, p):
    return v.read("USERS", "rating", (p["uid"],))


def update_rating(v, p):
    v.add("USERS", "rating", (p["uid"],), p["delta"])
    return 0


def sell_item(v, p):
    v.write("AUCTIONS", "seller", (p["iid"],), p["uid"])
    v.write("AUCTIONS", "n_bids", (p["iid"],), 0)
    return 0


def store_bid(v, p):
    """Dual-key (uid, iid): reads/writes the auction row AND the bidder row."""
    cur = v.read("AUCTIONS", "max_bid", (p["iid"],))
    new = v.where(p["amt"] > cur, p["amt"], cur)
    v.write("AUCTIONS", "max_bid", (p["iid"],), new)
    v.add("AUCTIONS", "n_bids", (p["iid"],), 1)
    v.write("BIDS", "amount", (p["uid"], p["iid"]), p["amt"])
    v.add("USERS", "balance", (p["uid"],), -p["amt"])
    return new


def search_items(v, p):
    # global catalogue scan over seller listings (written by sellItem, which
    # therefore replicates — paper: "a global search for items").
    s = 0
    for i in range(4):
        s = s + v.read("AUCTIONS", "seller", (i,))
    return s


def view_user_bids(v, p):
    """Paper's "browsing through a user's own bought items"."""
    s = 0
    for i in range(3):
        s = s + v.read("BIDS", "amount", (p["uid"], i))
    return s


def browse_categories(v, p):
    return v.read("CATEGORIES", "name_id", (p["cat"],))


def log_view(v, p):
    v.add("VIEW_LOG", "hits", (p["slot"],), 1)
    return 0


TXNS = (
    Transaction("viewProfile", ("uid",), view_profile, weight=20),
    Transaction("updateRating", ("uid", "delta"), update_rating, weight=5,
                max_writes=1),
    Transaction("sellItem", ("uid", "iid"), sell_item, weight=5, max_writes=2),
    Transaction("storeBid", ("uid", "iid", "amt"), store_bid, weight=8,
                max_writes=4),
    Transaction("searchItems", (), search_items, weight=4),
    Transaction("viewUserBids", ("uid",), view_user_bids, weight=6),
    Transaction("browseCategories", ("cat",), browse_categories, weight=10),
    Transaction("logView", ("slot",), log_view, weight=5, max_writes=1),
)


def init_arrays() -> dict:
    cats = (np.arange(16, dtype=np.int32) + 500).reshape(16, 1)
    return {"CATEGORIES": cats}


def sample_ops(n: int, seed: int = 0) -> list:
    rng = np.random.default_rng(seed)
    w = np.array([t.weight for t in TXNS], float)
    w /= w.sum()
    ops = []
    for _ in range(n):
        name = str(rng.choice([t.name for t in TXNS], p=w))
        if name in ("viewProfile", "updateRating", "viewUserBids"):
            p = {"uid": int(rng.integers(N_USERS))}
            if name == "updateRating":
                p["delta"] = int(rng.integers(1, 5))
        elif name == "sellItem":
            p = {"uid": int(rng.integers(N_USERS)), "iid": int(rng.integers(N_ITEMS))}
        elif name == "storeBid":
            p = {"uid": int(rng.integers(N_USERS)), "iid": int(rng.integers(N_ITEMS)),
                 "amt": int(rng.integers(1, 100))}
        elif name == "browseCategories":
            p = {"cat": int(rng.integers(16))}
        elif name == "logView":
            p = {"slot": int(rng.integers(32))}
        else:
            p = {}
        ops.append((name, p))
    return ops
