"""Synthetic micro-benchmark workload (paper §7.3, Figs. 5–6): a single
table; ``localOp(k)`` is perfectly partitionable by k, ``globalOp`` writes a
shared row.  The local-ratio parameter reproduces the paper's sweep."""
from __future__ import annotations

import numpy as np

from ..rwsets import Transaction
from ..state import Database, TableSchema

N_ROWS = 256


def make_db() -> Database:
    return Database(
        tables=(
            TableSchema("KV", ("val",), ("k",), (N_ROWS,)),
            TableSchema("SHARED", ("val",), ("k",), (8,)),
        )
    )


def local_op(v, p):
    v.add("KV", "val", (p["k"],), p["d"])
    return v.read("KV", "val", (p["k"],))


def global_op(v, p):
    # second write via a derived key (⊥ atom) keeps this op global under any
    # partitioning — the paper's fixed global fraction.
    v.add("SHARED", "val", (p["g"],), p["d"])
    v.add("SHARED", "val", ((p["g"] + 1) % 8,), p["d"])
    return v.read("SHARED", "val", (p["g"],))


TXNS = (
    Transaction("localOp", ("k", "d"), local_op, weight=1, max_writes=1),
    Transaction("globalOp", ("g", "d"), global_op, weight=1, max_writes=2),
)


def sample_ops(n: int, local_ratio: float, seed: int = 0) -> list:
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(n):
        if rng.random() < local_ratio:
            ops.append(
                ("localOp", {"k": int(rng.integers(N_ROWS)),
                             "d": int(rng.integers(1, 10))})
            )
        else:
            ops.append(
                ("globalOp", {"g": int(rng.integers(8)),
                              "d": int(rng.integers(1, 10))})
            )
    return ops
