"""TPC-W analogue (paper §6): online bookstore as a state machine over the
dense store.  Transaction mix mirrors the paper's shopping mix structure:
local ops partitioned by cart/customer id, global ops touching shared stock,
commutative ops on immutable/log tables.  Algorithm 1 classifies the 16
transactions 9 L / 3 G / 4 C — the paper's Table 1 structure (10/5/5 of
20) — incl. the worked createCart/doCart example of §3.1."""
from __future__ import annotations

import numpy as np

from ..rwsets import Transaction
from ..state import Database, TableSchema

N_CUST, N_ITEMS, N_CARTS, MAX_LINE = 64, 32, 64, 8


def make_db() -> Database:
    return Database(
        tables=(
            TableSchema("CUSTOMERS", ("balance", "ltd_spend"), ("c_id",), (N_CUST,)),
            TableSchema("ITEMS", ("stock", "price", "sold"), ("i_id",), (N_ITEMS,)),
            TableSchema("CARTS", ("total", "n_items", "owner"), ("sc_id",), (N_CARTS,)),
            TableSchema(
                "CART_LINES", ("qty",), ("sc_id", "i_id"), (N_CARTS, N_ITEMS)
            ),
            TableSchema("ORDERS", ("customer", "total", "status"), ("o_id",), (N_CARTS,)),
            TableSchema(
                "STATIC", ("content",), ("page_id",), (16,), immutable=True
            ),
            TableSchema("CLICK_LOG", ("hits",), ("slot",), (32,), write_only=True),
        )
    )


# --- transactions (paper §3.1 running example uses createCart/doCart) -------

def create_cart(v, p):
    v.write("CARTS", "owner", (p["sid"],), p["cid"])
    v.write("CARTS", "n_items", (p["sid"],), 0)
    return p["sid"]


def do_cart(v, p):
    """UPDATE SHOPPING_CARTS SET QTY = q WHERE ID = sid AND I_ID = iid."""
    stock = v.read("ITEMS", "stock", (p["iid"],))  # reads-from order (remote ok)
    q = v.where(stock >= p["q"], p["q"], 0)
    v.write("CART_LINES", "qty", (p["sid"], p["iid"]), q)
    v.add("CARTS", "n_items", (p["sid"],), 1)
    return q


def get_cart(v, p):
    return v.read("CARTS", "n_items", (p["sid"],))


def update_customer(v, p):
    v.add("CUSTOMERS", "balance", (p["cid"],), p["delta"])
    return 0


def get_customer(v, p):
    return v.read("CUSTOMERS", "balance", (p["cid"],))


def do_buy_confirm(v, p):
    """Global: drains the cart into an order, decrementing shared stock
    (write-write with every other order on the same items)."""
    total = 0
    for i in range(2):  # bounded cart scan (static unrolling)
        iid = (p["sid"] + i) % N_ITEMS  # derived key → unbound atom (⊥)
        qty = v.read("CART_LINES", "qty", (p["sid"], iid))
        price = v.read("ITEMS", "price", (iid,))
        v.add("ITEMS", "stock", (iid,), -qty)
        v.add("ITEMS", "sold", (iid,), qty)
        total = total + qty * price
    v.write("ORDERS", "customer", (p["sid"],), p["cid"])
    v.write("ORDERS", "total", (p["sid"],), total)
    v.write("ORDERS", "status", (p["sid"],), 1)
    return total


def admin_update_item(v, p):
    v.write("ITEMS", "price", (p["iid"],), p["price"])
    return 0


def get_best_sellers(v, p):
    s = 0
    for i in range(4):
        s = s + v.read("ITEMS", "sold", (i,))
    return s


def get_static(v, p):
    return v.read("STATIC", "content", (p["page"],))


def log_click(v, p):
    v.add("CLICK_LOG", "hits", (p["slot"],), 1)
    return 0


def get_orders(v, p):
    """Customer order history — local by the order key (= cart id here)."""
    return v.read("ORDERS", "status", (p["sid"],))


def refresh_cart(v, p):
    """Cart touch (paper: updating carts dominates the shopping mix)."""
    v.add("CARTS", "total", (p["sid"],), p["delta"])
    return v.read("CARTS", "total", (p["sid"],))


def clear_cart_line(v, p):
    v.write("CART_LINES", "qty", (p["sid"], p["iid"]), 0)
    return 0


def admin_restock(v, p):
    """Admin restock: shared stock write → global (like adminUpdateItem)."""
    v.add("ITEMS", "stock", (p["iid"],), p["qty"])
    v.add("ITEMS", "stock", ((p["iid"] + 1) % N_ITEMS,), 0)
    return 0


def get_related(v, p):
    """Static related-items page (immutable catalogue graph)."""
    return v.read("STATIC", "content", ((p["page"] + 1) % 16,))


def log_search(v, p):
    v.add("CLICK_LOG", "hits", ((p["slot"] + 16) % 32,), 1)
    return 0


TXNS = (
    Transaction("createCart", ("sid", "cid"), create_cart, weight=4, max_writes=2),
    Transaction("doCart", ("sid", "iid", "q"), do_cart, weight=10, max_writes=2),
    Transaction("getCart", ("sid",), get_cart, weight=12),
    Transaction("updateCustomer", ("cid", "delta"), update_customer, weight=4,
                max_writes=1),
    Transaction("getCustomer", ("cid",), get_customer, weight=8),
    Transaction("doBuyConfirm", ("sid", "cid"), do_buy_confirm, weight=6,
                max_writes=7),
    Transaction("adminUpdateItem", ("iid", "price"), admin_update_item, weight=1,
                max_writes=1),
    Transaction("getBestSellers", (), get_best_sellers, weight=3),
    Transaction("getStatic", ("page",), get_static, weight=6),
    Transaction("logClick", ("slot",), log_click, weight=4, max_writes=1),
    Transaction("getOrders", ("sid",), get_orders, weight=4),
    Transaction("refreshCart", ("sid", "delta"), refresh_cart, weight=6,
                max_writes=1),
    Transaction("clearCartLine", ("sid", "iid"), clear_cart_line, weight=2,
                max_writes=1),
    Transaction("adminRestock", ("iid", "qty"), admin_restock, weight=1,
                max_writes=2),
    Transaction("getRelated", ("page",), get_related, weight=3),
    Transaction("logSearch", ("slot",), log_search, weight=2, max_writes=1),
)


def init_arrays() -> dict:
    items = np.zeros((N_ITEMS, 3), np.int32)
    items[:, 0] = 100  # stock
    items[:, 1] = 1 + np.arange(N_ITEMS) % 7  # price
    static = np.arange(16 * 1, dtype=np.int32).reshape(16, 1) + 1000
    return {"ITEMS": items, "STATIC": static}


def sample_ops(n: int, seed: int = 0) -> list:
    """Shopping-mix-style stream (~30% writes, paper §7)."""
    rng = np.random.default_rng(seed)
    ops = []
    mix = [t.name for t in TXNS]
    w = np.array([t.weight for t in TXNS], float)
    w /= w.sum()
    for _ in range(n):
        name = rng.choice(mix, p=w)
        p = {}
        if name == "createCart":
            p = {"sid": int(rng.integers(N_CARTS)), "cid": int(rng.integers(N_CUST))}
        elif name == "doCart":
            p = {"sid": int(rng.integers(N_CARTS)), "iid": int(rng.integers(N_ITEMS)),
                 "q": int(rng.integers(1, 4))}
        elif name == "getCart":
            p = {"sid": int(rng.integers(N_CARTS))}
        elif name == "updateCustomer":
            p = {"cid": int(rng.integers(N_CUST)), "delta": int(rng.integers(1, 10))}
        elif name == "getCustomer":
            p = {"cid": int(rng.integers(N_CUST))}
        elif name == "doBuyConfirm":
            p = {"sid": int(rng.integers(N_CARTS)), "cid": int(rng.integers(N_CUST))}
        elif name == "adminUpdateItem":
            p = {"iid": int(rng.integers(N_ITEMS)), "price": int(rng.integers(1, 9))}
        elif name == "getStatic":
            p = {"page": int(rng.integers(16))}
        elif name == "logClick":
            p = {"slot": int(rng.integers(32))}
        elif name == "getOrders":
            p = {"sid": int(rng.integers(N_CARTS))}
        elif name == "refreshCart":
            p = {"sid": int(rng.integers(N_CARTS)), "delta": int(rng.integers(1, 5))}
        elif name == "clearCartLine":
            p = {"sid": int(rng.integers(N_CARTS)), "iid": int(rng.integers(N_ITEMS))}
        elif name == "adminRestock":
            p = {"iid": int(rng.integers(N_ITEMS)), "qty": int(rng.integers(1, 20))}
        elif name == "getRelated":
            p = {"page": int(rng.integers(16))}
        elif name == "logSearch":
            p = {"slot": int(rng.integers(32))}
        ops.append((str(name), p))
    return ops
