"""Algorithm 1 of the paper: conflict detection + partitioning optimization.

Faithful structure:
  * conflict detection builds, for every transaction pair (t, t') including
    t = t', a condition C_{t,t'} in DNF — one clause per pair of overlapping
    read/write entries, each clause a conjunction of per-key-attribute atom
    pairs ``(A = k_t) ∧ (A = k_{t'})``;
  * the optimizer searches operation-partitioning arrays P (one partitioning
    parameter per transaction) and removes every clause containing
    ``(k = A ∧ k' = A ∧ ...)`` with k = P[t], k' = P[t'] — such conflicts
    become partition-local under the shared deterministic routing function;
  * cost(P) = Σ weight(t) + weight(t') over conflicts that stay satisfiable
    (paper line 20); exhaustive search (feasible for OLTP-sized apps, as the
    paper argues), with an optional beam fallback for very wide apps.

Extensions kept from the paper's text: per-transaction frequency weights,
self-conflicts, constants (two distinct constants on the same key attribute
make a clause unsatisfiable), and the multi-parameter ("dual-key") scheme of
§3.1/§6 used by RUBiS: a second parameter that covers all residual clauses of
a transaction makes it local-iff-co-routed at runtime.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Sequence

from .rwsets import Binding, Entry, RWSets, Transaction

# clause kinds: 'ww' write/write, 'rf' (t reads from t'), 'fr' (t' reads from t)
KINDS = ("ww", "rf", "fr")


@dataclasses.dataclass(frozen=True)
class Clause:
    table: str
    # per key attribute of `table`: (key_attr, binding_t, binding_t2)
    atoms: tuple
    kind: str

    def satisfiable(self) -> bool:
        for _, b1, b2 in self.atoms:
            if (
                b1 is not None
                and b2 is not None
                and b1[0] == "const"
                and b2[0] == "const"
                and b1[1] != b2[1]
            ):
                return False
        return True

    def eliminated_by(self, k_t: str | None, k_t2: str | None) -> bool:
        """True iff the clause contains (k = A ∧ k' = A) for the chosen
        partitioning parameters — co-routing makes the conflict local."""
        if k_t is None or k_t2 is None:
            return False
        for _, b1, b2 in self.atoms:
            if b1 == ("param", k_t) and b2 == ("param", k_t2):
                return True
        return False


@dataclasses.dataclass(frozen=True)
class Conflict:
    t: str
    t2: str
    clauses: tuple  # of Clause


def _clause(table_keys: Sequence[str], table: str, e1: Entry, e2: Entry, kind: str):
    b1 = e1.bindings_for(table)
    b2 = e2.bindings_for(table)
    atoms = tuple((ka, b1.get(ka), b2.get(ka)) for ka in table_keys)
    return Clause(table, atoms, kind)


def detect_conflicts(
    db, txns: Sequence[Transaction], rwsets: Mapping[str, RWSets]
) -> list[Conflict]:
    """Phase 1 of Algorithm 1 (lines 1–10)."""
    conflicts = []
    names = [t.name for t in txns]
    for i, t in enumerate(txns):
        for t2 in txns[i:]:
            clauses = []
            r1, w1 = rwsets[t.name].reads, rwsets[t.name].writes
            r2, w2 = rwsets[t2.name].reads, rwsets[t2.name].writes
            for ea, eb, kind in itertools.chain(
                ((a, b, "rf") for a in r1 for b in w2),
                ((a, b, "fr") for a in w1 for b in r2),
                ((a, b, "ww") for a in w1 for b in w2),
            ):
                shared = {tb for tb, _ in ea.attrs} & {tb for tb, _ in eb.attrs}
                overlap = ea.attrs & eb.attrs
                if not overlap:
                    continue
                for table in sorted({tb for tb, _ in overlap}):
                    schema = db.table(table)
                    if schema.immutable or schema.write_only:
                        # Immutable reads / never-read log writes cannot
                        # conflict (paper's commutative examples).
                        continue
                    cl = _clause(schema.key_attrs, table, ea, eb, kind)
                    if cl.satisfiable():
                        clauses.append(cl)
                del shared
            if clauses:
                conflicts.append(Conflict(t.name, t2.name, tuple(clauses)))
    del names
    return conflicts


def residual_clauses(conflict: Conflict, P: Mapping[str, str | None]) -> list:
    k_t, k_t2 = P.get(conflict.t), P.get(conflict.t2)
    return [c for c in conflict.clauses if not c.eliminated_by(k_t, k_t2)]


def cost(
    P: Mapping[str, str | None],
    conflicts: Sequence[Conflict],
    weights: Mapping[str, float],
) -> float:
    """Paper Algorithm 1, function cost (lines 12–20)."""
    total = 0.0
    for cf in conflicts:
        if residual_clauses(cf, P):
            total += weights[cf.t] + weights[cf.t2]
    return total


def candidate_params(txn: Transaction, rw: RWSets) -> list[str | None]:
    """Parameters usable for partitioning: those appearing in equality atoms
    (paper: "potential partitioning parameters are involved in WHERE clauses
    only in atomic conditions in an equality form")."""
    cands = []
    for e in tuple(rw.reads) + tuple(rw.writes):
        for atom in e.cond:
            if atom.binding is not None and atom.binding[0] == "param":
                name = atom.binding[1]
                if name not in cands:
                    cands.append(name)
    return cands + [None]


def optimize_partitioning(
    db,
    txns: Sequence[Transaction],
    rwsets: Mapping[str, RWSets],
    max_exhaustive: int = 2_000_000,
) -> tuple[dict, list[Conflict], float]:
    """Phase 2 of Algorithm 1 (line 11): argmin_P cost(P, Conflicts).

    Exhaustive over the product of candidate parameters; greedy
    coordinate-descent fallback when the space exceeds ``max_exhaustive``
    (the paper notes "more sophisticated search strategies" are possible).
    """
    conflicts = detect_conflicts(db, txns, rwsets)
    weights = {t.name: t.weight for t in txns}
    cand = {t.name: candidate_params(t, rwsets[t.name]) for t in txns}
    names = [t.name for t in txns]

    space = 1
    for n in names:
        space *= len(cand[n])

    if space <= max_exhaustive:
        best, best_cost = None, float("inf")
        for combo in itertools.product(*(cand[n] for n in names)):
            P = dict(zip(names, combo))
            c = cost(P, conflicts, weights)
            if c < best_cost:
                best, best_cost = P, c
        assert best is not None
        return best, conflicts, best_cost

    # Greedy coordinate descent from the first-parameter heuristic.
    P = {n: cand[n][0] for n in names}
    improved = True
    while improved:
        improved = False
        for n in names:
            cur = cost(P, conflicts, weights)
            for k in cand[n]:
                trial = dict(P, **{n: k})
                if cost(trial, conflicts, weights) < cur:
                    P, cur, improved = trial, cost(trial, conflicts, weights), True
    return P, conflicts, cost(P, conflicts, weights)


def find_dual_keys(
    txns: Sequence[Transaction],
    rwsets: Mapping[str, RWSets],
    conflicts: Sequence[Conflict],
    P: Mapping[str, str | None],
) -> dict:
    """Multi-parameter post-pass (paper §3.1 "Multiple partitioning
    parameters", §6 RUBiS double-key scheme): a transaction with residual
    clauses gets a secondary parameter if routing by it would eliminate every
    residual clause — at runtime the operation is local iff both parameters
    route to the same server, global otherwise."""
    secondary: dict[str, str | None] = {}
    for t in txns:
        n = t.name
        residual = []
        for cf in conflicts:
            if n in (cf.t, cf.t2):
                residual.extend(
                    (cf, c) for c in residual_clauses(cf, P)
                )
        if not residual:
            secondary[n] = None
            continue
        found = None
        for k2 in candidate_params(t, rwsets[n])[:-1]:
            if k2 == P.get(n):
                continue
            ok = True
            for cf, c in residual:
                if cf.t == cf.t2 == n:
                    k_left, k_right = k2, k2
                elif cf.t == n:
                    k_left, k_right = k2, P.get(cf.t2)
                else:
                    k_left, k_right = P.get(cf.t), k2
                if not c.eliminated_by(k_left, k_right):
                    ok = False
                    break
            if ok:
                found = k2
                break
        secondary[n] = found
    return secondary
