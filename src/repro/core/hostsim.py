"""Discrete-event simulator for the paper's evaluation (§7).

The in-JAX belt proves semantics; timing behaviour at cluster scale (LAN /
WAN, token circulation, 2PC lock blocking) is a host-level concern — the
paper itself measures a middleware, so we reproduce its experiments with a
calibrated event simulator:

* ``conveyor``   — Eliá: local/commutative ops execute at their server with
                   no coordination; global ops wait for the token; the token
                   hop costs one inter-server latency; queued globals execute
                   as a parallel batch (paper §5 "Parallelizing the execution
                   of global operations").
* ``twopc``      — MySQL-Cluster analogue: single-partition ops run locally;
                   distributed transactions lock every involved partition for
                   2 round trips (prepare + commit) plus execution, blocking
                   conflicting work (read-only ops don't lock — read
                   committed, the paper's note on RUBiS).
* ``central``    — one server takes everything (WAN baseline 1).
* ``readonly``   — read-only ops served locally, writes forwarded to a
                   primary (WAN baseline 2, paper's "read-only" setting).

Closed-loop clients (paper: "we intensify the workload by increasing the
number of clients"); peak throughput = max sustained rate with mean latency
under the paper's 2000 ms bound.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Sequence

import numpy as np

# Paper Table 2 (ms): sites G, J, US, B, A; symmetric; intra-site 20.
SITES = ("G", "J", "US", "B", "A")
WAN_MS = np.array(
    [
        [20, 253, 92, 193, 314],
        [253, 20, 153, 282, 188],
        [92, 153, 20, 145, 229],
        [193, 282, 145, 20, 322],
        [314, 188, 229, 322, 20],
    ],
    dtype=float,
)
LAN_MS = np.full((5, 5), 0.5) + np.eye(5) * 0.0  # same-DC fabric
INTRA_MS = 20.0  # paper: intra-site latency ~20 ms (client ↔ server)


@dataclasses.dataclass(frozen=True)
class SimOp:
    is_global: bool
    home: int  # owning server
    read_only: bool
    partitions: tuple  # partitions touched (for 2PC locking)


@dataclasses.dataclass
class SimResult:
    throughput: float  # ops / s
    mean_latency_ms: float
    p99_latency_ms: float
    n_done: int
    mean_local_ms: float = 0.0
    mean_global_ms: float = 0.0


def latency(n_servers: int, wan: bool) -> np.ndarray:
    """Inter-server one-way latency matrix for n servers placed on the
    paper's sites round-robin (WAN) or inside one DC (LAN)."""
    base = WAN_MS if wan else LAN_MS
    site = [i % 5 for i in range(n_servers)]
    out = np.zeros((n_servers, n_servers))
    for i in range(n_servers):
        for j in range(n_servers):
            out[i, j] = base[site[i], site[j]] if i != j else 0.0
    return out


def client_latency(n_servers: int, wan: bool, client_site: int, server: int):
    if not wan:
        return INTRA_MS / 2
    s_site = server % 5
    return (INTRA_MS / 2) if s_site == client_site else WAN_MS[client_site, s_site] / 2


class _EventSim:
    """Shared machinery: closed-loop clients + per-server processor pool."""

    def __init__(self, n_servers, n_clients, exec_ms, wan, seed, server_slots=8):
        self.n = n_servers
        self.exec_ms = exec_ms
        self.wan = wan
        self.rng = np.random.default_rng(seed)
        self.lat = latency(n_servers, wan)
        self.events: list = []
        self.counter = itertools.count()
        self.now = 0.0
        self.latencies: list[float] = []
        self.local_lat: list[float] = []
        self.global_lat: list[float] = []
        self.n_clients = n_clients
        self.client_site = [i % 5 for i in range(n_clients)]
        self.server_free = np.zeros((n_servers, server_slots))

    def push(self, t, kind, payload):
        heapq.heappush(self.events, (t, next(self.counter), kind, payload))

    def service(self, server: int, t: float, dur: float) -> float:
        """Acquire the earliest-free processor slot; returns completion."""
        slots = self.server_free[server]
        k = int(np.argmin(slots))
        start = max(t, slots[k])
        slots[k] = start + dur
        return start + dur

    def done(self, client, issue_t, t, is_global):
        lat = t - issue_t
        self.latencies.append(lat)
        (self.global_lat if is_global else self.local_lat).append(lat)

    def result(self, duration_ms) -> SimResult:
        lat = np.array(self.latencies) if self.latencies else np.array([0.0])
        return SimResult(
            throughput=len(self.latencies) / (duration_ms / 1000.0),
            mean_latency_ms=float(lat.mean()),
            p99_latency_ms=float(np.percentile(lat, 99)),
            n_done=len(self.latencies),
            mean_local_ms=float(np.mean(self.local_lat)) if self.local_lat else 0.0,
            mean_global_ms=float(np.mean(self.global_lat)) if self.global_lat else 0.0,
        )


def simulate(
    protocol: str,
    op_source: Callable[[np.random.Generator], SimOp],
    n_servers: int,
    n_clients: int,
    duration_ms: float = 60_000.0,
    exec_ms: float = 5.0,
    wan: bool = False,
    seed: int = 0,
    token_batch_overhead_ms: float = 0.5,
) -> SimResult:
    sim = _EventSim(n_servers, n_clients, exec_ms, wan, seed)
    rng = sim.rng

    # protocol-specific shared state
    global_q: list[list] = [[] for _ in range(n_servers)]  # conveyor queues
    lock_until = np.zeros(n_servers)  # 2PC partition locks

    def nearest_server(client):
        site = sim.client_site[client]
        cands = [s for s in range(n_servers) if s % 5 == site % 5]
        if cands:
            return cands[client % len(cands)]
        return int(np.argmin([WAN_MS[site % 5, s % 5]
                              for s in range(n_servers)]))

    def issue(client, t):
        op = op_source(rng)
        if n_servers == 1:
            op = dataclasses.replace(op, is_global=False, home=0,
                                     partitions=(0,))
        elif wan and not op.is_global and protocol in ("conveyor", "twopc"):
            # Paper §6: Eliá generates server-specific unique ids so a
            # client's partitioned data lives at its closest server — local
            # ops are site-affine in the WAN experiments.
            home = nearest_server(client)
            op = dataclasses.replace(
                op, home=home,
                partitions=(home,) + tuple(p for p in op.partitions
                                           if p != op.home)[: 0],
            )
        if protocol == "central":
            server = 0
        elif protocol == "readonly":
            server = nearest_server(client) if op.read_only else 0
        else:
            server = op.home
        c_lat = client_latency(n_servers, sim.wan, sim.client_site[client], server)
        sim.push(t + c_lat, "arrive", (client, t, op, server))

    def reply(client, issue_t, t, op, server):
        c_lat = client_latency(n_servers, sim.wan, sim.client_site[client], server)
        sim.push(t + c_lat, "reply", (client, issue_t, op))

    for c in range(n_clients):
        issue(c, rng.uniform(0, 5.0))

    if protocol == "conveyor":
        sim.push(0.0, "token", 0)

    while sim.events:
        t, _, kind, payload = heapq.heappop(sim.events)
        if t > duration_ms:
            break
        sim.now = t
        if kind == "arrive":
            client, issue_t, op, server = payload
            if protocol == "conveyor" and op.is_global:
                global_q[server].append((client, issue_t, op))
            elif protocol == "twopc" and (not op.read_only) and len(op.partitions) > 1:
                # distributed transaction: lock all involved partitions for
                # 2 round trips + execution (pessimistic 2PC).
                rtt = 2 * max(sim.lat[server, p] for p in op.partitions)
                start = max(t, max(lock_until[p] for p in op.partitions))
                fin = start + 2 * rtt + exec_ms
                for p in op.partitions:
                    lock_until[p] = fin
                reply(client, issue_t, fin, op, server)
            else:
                if protocol == "twopc" and not op.read_only:
                    # single-partition write waits for partition lock
                    start = max(t, lock_until[op.partitions[0]])
                    fin = sim.service(server, start, exec_ms)
                else:
                    fin = sim.service(server, t, exec_ms)
                reply(client, issue_t, fin, op, server)
        elif kind == "token":
            holder = payload
            # batch-execute queued globals in parallel (paper §5)
            q, global_q[holder] = global_q[holder], []
            fin = t
            if q:
                fin = t + exec_ms + token_batch_overhead_ms * len(q)
                for client, issue_t, op in q:
                    reply(client, issue_t, fin, op, holder)
            nxt = (holder + 1) % n_servers
            sim.push(fin + max(sim.lat[holder, nxt], 0.25), "token", nxt)
        elif kind == "reply":
            client, issue_t, op = payload
            sim.done(client, issue_t, t, op.is_global)
            issue(client, t)

    return sim.result(duration_ms)


def peak_throughput(
    protocol: str,
    op_source,
    n_servers: int,
    wan: bool = False,
    exec_ms: float = 5.0,
    latency_bound_ms: float = 2000.0,
    client_grid: Sequence[int] = (4, 8, 16, 32, 64, 128, 256, 512),
    duration_ms: float = 30_000.0,
    seed: int = 0,
) -> tuple[float, SimResult]:
    """Paper's metric: max throughput with mean latency < 2000 ms."""
    best, best_res = 0.0, None
    for nc in client_grid:
        res = simulate(protocol, op_source, n_servers, nc, duration_ms,
                       exec_ms, wan, seed)
        if res.mean_latency_ms <= latency_bound_ms and res.throughput >= best:
            best, best_res = res.throughput, res
    if best_res is None:
        best_res = simulate(protocol, op_source, n_servers, client_grid[0],
                            duration_ms, exec_ms, wan, seed)
        best = best_res.throughput
    return best, best_res


# --- bridging real classified workloads into the simulator -----------------


def op_source_from_workload(
    engine, concrete_ops: Sequence, n_servers: int, extra_partitions=1, seed=0
):
    """Precompute SimOps for a stream of concrete (txn, params) ops: each is
    routed with the SAME deterministic routing as the JAX belt; 2PC partition
    sets follow the paper's setup (the data partitioning induced by operation
    partitioning).  The returned source cycles the pool randomly."""
    from .rwsets import extract_rwsets

    read_only = {}
    for txn in engine.txns:
        rw = extract_rwsets(engine.db, txn)
        read_only[txn.name] = len(rw.writes) == 0
    names = [t.name for t in engine.txns]
    prep_rng = np.random.default_rng(seed)

    pool = []
    for name, params in concrete_ops:
        ti = names.index(name)
        txn = engine.txns[ti]
        pv = np.zeros((engine.spec.max_params,), np.int32)
        for i, pn in enumerate(txn.params):
            pv[i] = params[pn]
        home, is_global = engine.route_np(ti, pv)
        if is_global and n_servers > 1:
            others = [p for p in range(n_servers) if p != home]
            k = min(extra_partitions, len(others))
            parts = (home, *prep_rng.choice(others, size=k, replace=False))
        else:
            parts = (home,)
        pool.append(
            SimOp(bool(is_global), int(home), read_only[name],
                  tuple(map(int, parts)))
        )

    def source(rng: np.random.Generator) -> SimOp:
        return pool[int(rng.integers(len(pool)))]

    return source
