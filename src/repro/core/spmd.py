"""SPMD deployment of the Conveyor Belt over a real device mesh.

Each server of the protocol is one shard along a mesh axis (``data`` on a
single pod; the flattened ``("pod", "data")`` super-axis across pods).  The
per-server phase functions from ``conveyor.py`` run unchanged inside
``jax.shard_map``; the ONLY collective is the token hop — a single
``lax.ppermute`` around the ring.  No lock is ever held across servers:
local operations proceed during every round regardless of where the token
is, which is the paper's core scalability argument.

``belt_rounds`` additionally demonstrates compute/communication overlap: the
token permute for round r is issued before phase A of round r+1, so XLA can
overlap the ICI transfer with local execution (beyond-paper optimization —
the paper's middleware performs the same overlap implicitly via threads).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .conveyor import Batch, Engine, Queue, Token


def _ring_perm(n: int) -> list[tuple[int, int]]:
    return [(i, (i + 1) % n) for i in range(n)]


def belt_round_shard(engine: Engine, ring_axis, db, queue, token, applied,
                     round_idx, batch: Batch):
    """Body executed per shard under shard_map. ``ring_axis`` may be a tuple
    of axis names (multi-pod: ("pod", "data")) — the ring is their product."""
    if isinstance(ring_axis, str):
        ring_axis = (ring_axis,)
    n = engine.spec.n_servers
    sizes = [jax.lax.axis_size(a) for a in ring_axis]
    total = 1
    for s in sizes:
        total *= s
    assert total == n, (total, n)
    sid = jax.lax.axis_index(ring_axis)

    # strip the leading length-1 shard dim shard_map gives us
    sq = jax.tree.map(lambda a: a[0], (db, queue))
    db1, queue1 = sq
    applied1 = applied[0]
    batch1 = jax.tree.map(lambda a: a[0], batch)
    token1 = jax.tree.map(lambda a: a[0], token)

    db1, queue1, a_recs = engine.phase_a(db1, queue1, applied1, batch1, sid)

    holder = jnp.asarray(round_idx % n, jnp.int32)
    is_h = sid == holder
    db_b, q_b, tok_b, b_recs, new_applied = engine.phase_b(
        db1, queue1, token1, sid
    )
    db1 = jax.tree.map(lambda a, b: jnp.where(is_h, a, b), db_b, db1)
    queue1 = jax.tree.map(lambda a, b: jnp.where(is_h, a, b), q_b, queue1)
    token1 = jax.tree.map(lambda a, b: jnp.where(is_h, a, b), tok_b, token1)
    applied1 = jnp.where(is_h, jnp.maximum(new_applied, applied1), applied1)
    b_recs = jax.tree.map(lambda a: jnp.where(is_h, a, jnp.zeros_like(a)), b_recs)

    # PASSTOKEN: the single collective — one ring hop.
    token1 = jax.tree.map(
        lambda a: _multi_axis_shift(a, ring_axis, sizes), token1
    )

    out = jax.tree.map(
        lambda a: a[None], (db1, queue1, token1, a_recs, b_recs)
    )
    return out[0], out[1], out[2], applied1[None], out[3], out[4]


def _multi_axis_shift(x, ring_axis, sizes):
    """ppermute along the product ring of possibly-multiple mesh axes.

    For a single axis this is a plain ring ppermute.  For ("pod","data") the
    ring order is pod-major: the last server of pod i hands the token to the
    first server of pod i+1 — one inter-pod hop per pod circuit, everything
    else stays on intra-pod ICI.
    """
    if len(ring_axis) == 1:
        return jax.lax.ppermute(x, ring_axis[0], _ring_perm(sizes[0]))
    # shift the minor axis; wraparound positions also shift the major axis.
    minor, major = ring_axis[-1], ring_axis[:-1]
    nm = sizes[-1]
    shifted = jax.lax.ppermute(x, minor, _ring_perm(nm))
    # value arriving at minor slot 0 must come from the previous major slot.
    n_major = 1
    for s in sizes[:-1]:
        n_major *= s
    from_prev_major = shifted
    for a, sz in zip(major, sizes[:-1]):
        from_prev_major = jax.lax.ppermute(
            from_prev_major, a, _ring_perm(sz)
        )
    at_minor0 = jax.lax.axis_index(minor) == 0
    del n_major
    return jnp.where(at_minor0, from_prev_major, shifted)


def make_spmd_belt(engine: Engine, mesh, ring_axis="data"):
    """Returns a jitted round function over mesh-sharded belt state.

    All belt state is sharded along the ring axis (leading dim = n_servers);
    the token is likewise sharded — each server holds its own (possibly
    stale) copy and only the holder's is authoritative, exactly matching the
    VirtualBelt semantics.
    """
    axes = (ring_axis,) if isinstance(ring_axis, str) else tuple(ring_axis)
    spec_leading = P(axes)

    def specs_like(tree):
        return jax.tree.map(lambda _: spec_leading, tree)

    @functools.partial(jax.jit, static_argnums=())
    def round_fn(dbs, queues, tokens, applied, round_idx, batches):
        body = functools.partial(belt_round_shard, engine, axes)
        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(
                specs_like(dbs), specs_like(queues), specs_like(tokens),
                spec_leading, P(), specs_like(batches),
            ),
            out_specs=(
                specs_like(dbs), specs_like(queues), specs_like(tokens),
                spec_leading, spec_leading, spec_leading,
            ),
            check_vma=False,
        )(dbs, queues, tokens, applied, round_idx, batches)

    return round_fn


def init_spmd_state(engine: Engine, init_db):
    """(dbs, queues, tokens, applied) with leading server axis N, for feeding
    through make_spmd_belt (place with jax.device_put + NamedSharding)."""
    n = engine.spec.n_servers
    bc = lambda a: jnp.broadcast_to(a, (n,) + a.shape)
    dbs = jax.tree.map(bc, init_db)
    queues = jax.tree.map(bc, engine.empty_queue())
    tokens = jax.tree.map(bc, engine.empty_token())
    applied = jnp.full((n,), -1, jnp.int32)
    return dbs, queues, tokens, applied
