"""Dense JAX state store: the "DBMS" each Conveyor Belt server owns.

The paper's servers each run an unmodified single-server DBMS.  Our TPU-native
analogue is a pytree of dense tables resident in a replica group's HBM.  Rows
are addressed by integer primary keys (multi-attribute keys are flattened with
a mixed radix), values are int32 so that serializability checks are exact.

A ``Database`` is immutable metadata; ``DbState`` is the JAX pytree of arrays.
All mutation goes through pure functions returning new states, so the store
composes with jit / scan / shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TableSchema:
    """Schema for one table.

    attrs: value columns (int32 each).
    key_attrs: primary-key attribute names (integer domains).
    key_card: cardinality of each key attribute (rows live in a dense
        ``prod(key_card)`` address space — the OLTP analogue of a hash index
        with a perfect hash).
    immutable: never written after init (⇒ reads of it are conflict-free).
    write_only: written but never read (⇒ log-like, conflict-free writes).
    """

    name: str
    attrs: tuple[str, ...]
    key_attrs: tuple[str, ...]
    key_card: tuple[int, ...]
    immutable: bool = False
    write_only: bool = False

    @property
    def capacity(self) -> int:
        out = 1
        for c in self.key_card:
            out *= int(c)
        return out

    def attr_index(self, attr: str) -> int:
        return self.attrs.index(attr)

    def flat_key(self, key: Sequence) -> jax.Array:
        """Mixed-radix flattening of a (possibly traced) composite key."""
        assert len(key) == len(self.key_card), (self.name, key)
        flat = None
        for k, card in zip(key, self.key_card):
            k = jnp.asarray(k, jnp.int32) % jnp.int32(card)
            flat = k if flat is None else flat * jnp.int32(card) + k
        return jnp.asarray(flat, jnp.int32)


@dataclasses.dataclass(frozen=True)
class Database:
    tables: tuple[TableSchema, ...]

    def __post_init__(self):
        names = [t.name for t in self.tables]
        assert len(set(names)) == len(names), "duplicate table names"

    def table(self, name: str) -> TableSchema:
        for t in self.tables:
            if t.name == name:
                return t
        raise KeyError(name)

    def table_id(self, name: str) -> int:
        for i, t in enumerate(self.tables):
            if t.name == name:
                return i
        raise KeyError(name)

    # -- state construction ------------------------------------------------
    def init_state(self, init: Mapping[str, np.ndarray] | None = None) -> "DbState":
        arrays = {}
        for t in self.tables:
            if init is not None and t.name in init:
                a = np.asarray(init[t.name], np.int32)
                assert a.shape == (t.capacity, len(t.attrs)), (t.name, a.shape)
                arrays[t.name] = jnp.asarray(a)
            else:
                arrays[t.name] = jnp.zeros((t.capacity, len(t.attrs)), jnp.int32)
        return DbState(arrays)

    # Max row capacity / attr count across tables: used for homogeneous
    # update-record encoding on the token.
    @property
    def max_attrs(self) -> int:
        return max(len(t.attrs) for t in self.tables)


@jax.tree_util.register_pytree_node_class
class DbState:
    """Pytree of per-table (capacity, n_attrs) int32 arrays."""

    def __init__(self, arrays: Mapping[str, jax.Array]):
        self.arrays = dict(arrays)

    def tree_flatten(self):
        keys = sorted(self.arrays)
        return [self.arrays[k] for k in keys], tuple(keys)

    @classmethod
    def tree_unflatten(cls, keys, children):
        return cls(dict(zip(keys, children)))

    # -- pure accessors ----------------------------------------------------
    def read(self, schema: TableSchema, attr: str, key: Sequence) -> jax.Array:
        row = schema.flat_key(key)
        return self.arrays[schema.name][row, schema.attr_index(attr)]

    def read_row(self, schema: TableSchema, key: Sequence) -> jax.Array:
        return self.arrays[schema.name][schema.flat_key(key)]

    def write(self, schema: TableSchema, attr: str, key: Sequence, value) -> "DbState":
        row = schema.flat_key(key)
        col = schema.attr_index(attr)
        arrays = dict(self.arrays)
        arrays[schema.name] = arrays[schema.name].at[row, col].set(
            jnp.asarray(value, jnp.int32)
        )
        return DbState(arrays)

    def add(self, schema: TableSchema, attr: str, key: Sequence, value) -> "DbState":
        row = schema.flat_key(key)
        col = schema.attr_index(attr)
        arrays = dict(self.arrays)
        arrays[schema.name] = arrays[schema.name].at[row, col].add(
            jnp.asarray(value, jnp.int32)
        )
        return DbState(arrays)

    def write_row(self, schema: TableSchema, key: Sequence, values) -> "DbState":
        row = schema.flat_key(key)
        arrays = dict(self.arrays)
        vals = jnp.asarray(values, jnp.int32)
        arrays[schema.name] = arrays[schema.name].at[row].set(vals)
        return DbState(arrays)

    def select(self, pred, other: "DbState") -> "DbState":
        """Row-wise jnp.where over two states (same schema)."""
        arrays = {
            k: jnp.where(pred, self.arrays[k], other.arrays[k]) for k in self.arrays
        }
        return DbState(arrays)


def states_equal(a: DbState, b: DbState) -> bool:
    return all(bool(jnp.array_equal(a.arrays[k], b.arrays[k])) for k in a.arrays)
