"""Flash attention forward — Pallas TPU kernel.

Tiling: grid (B, H, Sq/bq, Skv/bk); the kv axis is the innermost
(sequential) grid dimension, so the output block for a given (b, h, qi) is
revisited across kv steps and the online-softmax state (running max m,
denominator l, accumulator acc) lives in VMEM scratch.  Block sizes default
to (bq, bk) = (128, 128) with full head_dim per tile — MXU-aligned
(multiples of 128 on the contracting/lane dims).

GQA is handled by the q→kv head index map (h // group); causal masking
skips fully-masked kv blocks via the index map (blocks above the diagonal
are never fetched... they are fetched but masked; skipping is a TODO noted
in EXPERIMENTS §Perf).  Supports sliding windows and logit soft-capping
(gemma2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale, causal, window, logit_cap, bq, bk, n_kv):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if logit_cap is not None:
        s = jnp.tanh(s / logit_cap) * logit_cap

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_cap", "bq", "bk", "interpret"),
)
def flash_attention(q, k, v, *, causal=True, window=None, logit_cap=None,
                    bq=128, bk=128, interpret=False):
    """q: (B, Sq, H, hd); k, v: (B, Skv, Hkv, hd) → (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    assert H % Hkv == 0
    group = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    n_kv = Skv // bk

    # (B, H, S, hd) layout inside the kernel
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _fa_kernel, scale=hd ** -0.5, causal=causal, window=window,
        logit_cap=logit_cap, bq=bq, bk=bk, n_kv=n_kv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # denominator l
            pltpu.VMEM((bq, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
