"""Pure-jnp oracle for flash attention (naive full-score materialization)."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def flash_attention_ref(q, k, v, *, causal=True, window=None, logit_cap=None):
    """q: (B, Sq, H, hd); k, v: (B, Skv, Hkv, hd) → (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    if logit_cap is not None:
        s = jnp.tanh(s / logit_cap) * logit_cap
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    m = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        m &= qp >= kp
    if window is not None:
        m &= (qp - kp) < window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
