"""Jit'd public wrapper for the flash-attention kernel.

On CPU hosts (this container) the kernel executes in interpret mode — the
body is traced as jnp ops — while on TPU it lowers to Mosaic.  The wrapper
picks interpret automatically from the backend.
"""
from __future__ import annotations

import jax

from .kernel import flash_attention


def is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention_op(q, k, v, *, causal=True, window=None, logit_cap=None,
                       bq=128, bk=128):
    return flash_attention(
        q, k, v, causal=causal, window=window, logit_cap=logit_cap,
        bq=bq, bk=bk, interpret=is_cpu(),
    )
