"""Conveyor Belt delta-apply — Pallas TPU kernel.

Applies a batch of token state-update records (full-row after-images, paper
§5 "passive replication") onto an HBM-resident table shard.  The table is
tiled (bt rows × W) through VMEM via input↔output aliasing; record row-ids
are scalar-prefetched (SMEM) so each grid step can decide membership without
touching HBM.  Records are applied IN TOKEN ORDER within the tile (later
records overwrite earlier — the serializable order of the belt).

This is the hot loop of the protocol: every server applies every remote
global update once per rotation; fusing the scatter through VMEM avoids
read-modify-write round trips to HBM for hot rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _apply_kernel(rows_ref, valid_ref, table_ref, vals_ref, out_ref, *,
                  bt, n_records):
    ti = pl.program_id(0)
    tile = table_ref[...]  # (bt, W)
    lo = ti * bt

    def body(i, tile):
        row = rows_ref[i]
        ok = valid_ref[i] != 0
        in_tile = ok & (row >= lo) & (row < lo + bt)
        local = jnp.where(in_tile, row - lo, 0)
        new_row = jnp.where(in_tile, vals_ref[i].astype(tile.dtype),
                            tile[local])
        return tile.at[local].set(new_row)

    tile = jax.lax.fori_loop(0, n_records, body, tile)
    out_ref[...] = tile


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def delta_apply(table, rows, vals, valid, *, bt=256, interpret=False):
    """table: (R, W) int32; rows: (K,) int32; vals: (K, W) int32;
    valid: (K,) bool → updated table."""
    R, W = table.shape
    K = rows.shape[0]
    bt = min(bt, R)
    assert R % bt == 0
    rows = (rows % R).astype(jnp.int32)

    kernel = functools.partial(_apply_kernel, bt=bt, n_records=K)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(R // bt,),
        in_specs=[
            pl.BlockSpec((bt, W), lambda t, *_: (t, 0)),
            pl.BlockSpec((K, W), lambda t, *_: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, W), lambda t, *_: (t, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, W), table.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(rows, valid.astype(jnp.int32), table, vals)
