"""Jit'd wrapper for delta-apply (interpret on CPU)."""
from __future__ import annotations

import jax

from .kernel import delta_apply


def delta_apply_op(table, rows, vals, valid, *, bt=256):
    return delta_apply(table, rows, vals, valid, bt=bt,
                       interpret=jax.default_backend() == "cpu")
