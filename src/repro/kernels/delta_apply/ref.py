"""Oracle for the Conveyor Belt delta-apply: sequential row scatter."""
from __future__ import annotations

import jax.numpy as jnp


def delta_apply_ref(table, rows, vals, valid):
    """table: (R, W); rows: (K,); vals: (K, W); valid: (K,) — later records
    overwrite earlier ones (token order)."""
    out = table
    for i in range(rows.shape[0]):
        new = out.at[rows[i] % table.shape[0]].set(vals[i])
        out = jnp.where(valid[i], new, out)
    return out
