"""Jit'd wrapper for the SSD kernel (interpret on CPU)."""
from __future__ import annotations

import jax

from .kernel import ssd


def ssd_op(xh, dt, A_log, B, C, D, *, chunk=128):
    return ssd(xh, dt, A_log, B, C, D, chunk=chunk,
               interpret=jax.default_backend() == "cpu")
