"""Oracle for the Mamba2 SSD kernel: exact per-step recurrence."""
from __future__ import annotations

from repro.models.mamba2 import mamba2_ref_scan


def ssd_ref(xh, dt, A_log, B, C, D):
    """xh: (Bt,S,H,P); dt: (Bt,S,H); A_log,D: (H,); B,C: (Bt,S,N)."""
    return mamba2_ref_scan(xh, dt, A_log, B, C, D)
