"""Chunked Mamba2 SSD — Pallas TPU kernel.

Grid (B, n_chunks) with the chunk axis sequential: per chunk the kernel does
the intra-chunk attention-like matmuls on the MXU (decay-weighted C·Bᵀ and
the chunk-state outer products) and carries the (H, N, P) SSM state across
chunks in VMEM scratch (f32).  All heads are processed per tile — for
zamba2 (H=112, N=64, P=64) the state is 1.8 MB and the chunk working set
≈6 MB: inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, d_ref, o_ref,
                state_ref, *, chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # (Lc, H, P)
    dt = dt_ref[0].astype(jnp.float32)  # (Lc, H)
    a = -jnp.exp(alog_ref[...].astype(jnp.float32))  # (H,)
    Bm = b_ref[0].astype(jnp.float32)  # (Lc, N)
    Cm = c_ref[0].astype(jnp.float32)  # (Lc, N)
    Dh = d_ref[...].astype(jnp.float32)  # (H,)

    la = dt * a[None, :]  # (Lc, H) log decays
    cum = jnp.cumsum(la, axis=0)  # (Lc, H)
    total = cum[-1]  # (H,)
    xdt = x * dt[..., None]  # (Lc, H, P)

    # intra-chunk — mask the log-decay BEFORE exp: the upper triangle has
    # positive exponents that overflow to inf (inf·0 = NaN) otherwise.
    GB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Lc, Lc)
    idx_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    idx_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (idx_i >= idx_j)[:, :, None]
    ldec = cum[:, None, :] - cum[None, :, :]  # (i, j, H)
    M = GB[:, :, None] * jnp.exp(jnp.where(tri, ldec, -1e30))
    y = jnp.einsum("ijh,jhp->ihp", M, xdt)

    # inter-chunk from carried state
    h_prev = state_ref[...]  # (H, N, P)
    y += jnp.einsum("is,hsp->ihp", Cm, h_prev) * jnp.exp(cum)[..., None]

    # state update
    wx = jnp.exp(total[None, :] - cum)[..., None] * xdt  # (Lc, H, P)
    state_ref[...] = h_prev * jnp.exp(total)[:, None, None] + jnp.einsum(
        "js,jhp->hsp", Bm, wx
    )

    y += x * Dh[None, :, None]
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(xh, dt, A_log, B, C, D, *, chunk=128, interpret=False):
    """xh: (Bt,S,H,P); dt: (Bt,S,H); A_log,D: (H,); B,C: (Bt,S,N)."""
    Bt, S, H, P = xh.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    out = pl.pallas_call(
        kernel,
        grid=(Bt, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, S, H, P), xh.dtype),
        scratch_shapes=[pltpu.VMEM((H, N, P), jnp.float32)],
        interpret=interpret,
    )(xh, dt, A_log, B, C, D)
    return out
