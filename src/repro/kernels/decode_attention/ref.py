"""Oracle for single-token GQA decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, kv_len):
    """q: (B, H, hd); k, v: (B, S, Hkv, hd); kv_len: (B,) valid prefix.
    Returns (B, H, hd)."""
    B, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    mask = jnp.arange(S)[None, :] < kv_len[:, None]
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
