"""Flash-decode — Pallas TPU kernel for single-token GQA decode.

One new token attends to a long KV cache.  Tiling: grid (B, Hkv, S/bk) with
the kv-block axis innermost/sequential; all G = H/Hkv query heads of one kv
head are processed together as a (G, hd) tile (GQA keeps the MXU busy:
scores tile is (G, bk)).  Online softmax state (m, l, acc) in VMEM scratch;
``kv_len`` (SMEM, scalar-prefetched) masks the valid prefix so only written
cache slots contribute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                scale, bk, n_kv):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)  # (bk, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[b], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, kv_len, *, bk=256, interpret=False):
    """q: (B, H, hd); k, v: (B, S, Hkv, hd); kv_len: (B,) → (B, H, hd)."""
    B, H, hd = q.shape
    _, S, Hkv, _ = k.shape
    assert H % Hkv == 0
    G = H // Hkv
    bk = min(bk, S)
    assert S % bk == 0
    n_kv = S // bk

    qt = q.reshape(B, Hkv, G, hd)
    kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, S, hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_dec_kernel, scale=hd ** -0.5, bk=bk, n_kv=n_kv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki, *_: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki, *_: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qt, kt, vt)
    return out.reshape(B, H, hd)
