"""Jit'd wrapper for flash-decode (interpret on CPU, Mosaic on TPU)."""
from __future__ import annotations

import jax

from .kernel import decode_attention


def decode_attention_op(q, k, v, kv_len, *, bk=256):
    return decode_attention(
        q, k, v, kv_len, bk=bk, interpret=jax.default_backend() == "cpu"
    )
