"""Pallas TPU kernels for the perf-critical compute layers.

Each subpackage ships kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd wrapper with an ``interpret`` switch — True on CPU),
and ref.py (pure-jnp oracle).  tests/test_kernels.py sweeps shapes/dtypes
asserting allclose against the oracles.
"""
