"""WKV6 (RWKV-6 "Finch") — Pallas TPU kernel.

Grid (B, H, n_chunks), chunk axis sequential.  Within a chunk the recurrence
uses the matmul form with log-space decay ratios:

  y_t = (r_t ⊙ W_{t-1}) · S₀            (inter-chunk, MXU matmul)
      + Σ_{j<t} [(r_t ⊙ W_{t-1}/W_j) · k_j] v_j   (intra, masked matmul)
      + (r_t ⊙ u · k_t) v_t                       (bonus diagonal)

with W_t = Π_{s≤t} w_s per channel.  Ratios W_{t-1}/W_j (j<t) are ≤ 1 so the
exp stays stable; the per-pair exponent is evaluated inside the score einsum
over the head dim (chunk=32 keeps the (Lc, Lc, hd) decay tensor in VMEM).
State (hd, hd) f32 carried in scratch across chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(jnp.float32)  # (Lc, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)  # decays in (0,1)
    u = u_ref[0].astype(jnp.float32)  # (hd,)

    logw = jnp.log(jnp.maximum(w, 1e-38))  # (Lc, hd)
    cum = jnp.cumsum(logw, axis=0)  # W_t = exp(cum_t)
    cum_prev = cum - logw  # W_{t-1}

    # inter-chunk: y_t += (r_t ⊙ W_{t-1}) @ S0
    S0 = s_ref[...]  # (hd_k, hd_v)
    rw = r * jnp.exp(cum_prev)
    y = jax.lax.dot_general(rw, S0, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # intra-chunk: scores_tj = Σ_d r_t[d] k_j[d] exp(cum_prev_t - cum_j)[d]
    ratio = jnp.exp(cum_prev[:, None, :] - cum[None, :, :])  # (t, j, hd) ≤ 1
    scores = jnp.einsum("td,jd,tjd->tj", r, k, ratio)
    idx_t = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    idx_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(idx_t > idx_j, scores, 0.0)
    # bonus diagonal
    diag = jnp.sum(r * u[None, :] * k, axis=-1)  # (Lc,)
    y += jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y += diag[:, None] * v

    # state update: S = diag(W_L) S0 + Σ_j (W_L / W_j ⊙ k_j)ᵀ v_j
    wl = jnp.exp(cum[-1])  # (hd,)
    kd = k * jnp.exp(cum[-1][None, :] - cum)  # (Lc, hd), ratios ≤ 1
    s_ref[...] = wl[:, None] * S0 + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk=32, interpret=False):
    """r,k,v,w: (B,S,H,hd); u: (H,hd) → y (B,S,H,hd)."""
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    tr = lambda t: t.transpose(0, 2, 1, 3)  # (B,H,S,hd)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(tr(r), tr(k), tr(v), tr(w), u)
    return out.transpose(0, 2, 1, 3)
