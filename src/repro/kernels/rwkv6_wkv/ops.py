"""Jit'd wrapper for the WKV6 kernel (interpret on CPU)."""
from __future__ import annotations

import jax

from .kernel import wkv6


def wkv6_op(r, k, v, w, u, *, chunk=32):
    return wkv6(r, k, v, w, u, chunk=chunk,
                interpret=jax.default_backend() == "cpu")
