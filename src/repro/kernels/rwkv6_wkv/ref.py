"""Oracle for the WKV6 kernel: exact per-step recurrence."""
from __future__ import annotations

from repro.models.rwkv6 import wkv6_scan


def wkv6_ref(r, k, v, w, u):
    """r,k,v,w: (B,S,H,hd); u: (H,hd) → (y, final_state)."""
    return wkv6_scan(r, k, v, w, u)
