from .driver import FTConfig, TrainDriver  # noqa: F401
from .straggler import StragglerMonitor  # noqa: F401
