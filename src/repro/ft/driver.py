"""Fault-tolerant training driver.

Wraps a compiled train step with: periodic (async) checkpointing, restart
from the latest checkpoint (bit-exact: data pipeline is a pure function of
the step counter), failure injection for tests, straggler monitoring, and an
elastic-restart path (restore re-shards onto whatever mesh the new process
has — see checkpointing.restore).

This is the host-side control plane; the paper delegates per-server fault
tolerance to exactly this kind of layer ("a Paxos group could implement the
abstraction of a logical fault tolerant server", §4).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpointing import latest_step, restore, save
from .straggler import StragglerMonitor


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    async_ckpt: bool = False
    keep: int = 3
    fail_at_step: int | None = None  # failure injection (tests)


class TrainDriver:
    def __init__(
        self,
        step_fn: Callable,  # (params, opt, batch) -> (params, opt, metrics)
        batch_fn: Callable,  # step -> batch
        params,
        opt_state,
        ft: FTConfig,
        shardings=None,  # (param_sh, opt_sh) for elastic restore
    ):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.params = params
        self.opt_state = opt_state
        self.ft = ft
        self.shardings = shardings
        self.step = 0
        self.monitor = StragglerMonitor(n=1)
        self.history: list[dict] = []
        self._pending_ckpt = None

    # -- recovery -------------------------------------------------------------
    def maybe_resume(self) -> bool:
        s = latest_step(self.ft.ckpt_dir)
        if s is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state}
        sh = (
            {"params": self.shardings[0], "opt": self.shardings[1]}
            if self.shardings
            else None
        )
        out = restore(self.ft.ckpt_dir, s, tree, sh)
        self.params, self.opt_state = out["params"], out["opt"]
        self.step = s
        return True

    def _checkpoint(self):
        if self._pending_ckpt is not None:
            self._pending_ckpt.join()
        self._pending_ckpt = save(
            self.ft.ckpt_dir,
            self.step,
            {"params": self.params, "opt": self.opt_state},
            async_write=self.ft.async_ckpt,
            keep=self.ft.keep,
        )

    # -- main loop -------------------------------------------------------------
    def run(self, n_steps: int) -> list[dict]:
        end = self.step + n_steps
        while self.step < end:
            if self.ft.fail_at_step is not None and self.step == self.ft.fail_at_step:
                self.ft.fail_at_step = None  # fail once
                raise InjectedFailure(f"injected failure at step {self.step}")
            t0 = time.time()
            batch = self.batch_fn(self.step)
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            dt = time.time() - t0
            self.monitor.observe(0, dt)
            metrics.update(step=self.step, seconds=dt)
            self.history.append(metrics)
            self.step += 1
            if self.step % self.ft.ckpt_every == 0:
                self._checkpoint()
        jax.block_until_ready(jax.tree.leaves(self.params)[0])
        self._checkpoint()
        if self._pending_ckpt is not None:
            self._pending_ckpt.join()
        return self.history
