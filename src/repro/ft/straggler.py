"""Straggler detection + mitigation policy.

Step (or token-hop) latencies per participant feed an EWMA; a participant
whose latency exceeds ``threshold ×`` the fleet median is flagged.  Paired
with the Conveyor Belt: the mitigation for a straggling *token holder* is to
skip its execution turn for a rotation — the belt's design makes this safe
(the skipped server's global ops simply wait one more rotation in its queue;
local traffic everywhere is never blocked, which is the paper's core
property).  For sync-DP the mitigation is the classic backup-step /
checkpoint-evict decision, surfaced as an action for the driver.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    n: int
    alpha: float = 0.2
    threshold: float = 2.0
    warmup: int = 3

    def __post_init__(self):
        self.ewma = np.zeros(self.n)
        self.count = np.zeros(self.n, dtype=int)

    def observe(self, participant: int, latency_s: float) -> None:
        e = self.ewma[participant]
        self.ewma[participant] = (
            latency_s if self.count[participant] == 0
            else (1 - self.alpha) * e + self.alpha * latency_s
        )
        self.count[participant] += 1

    def stragglers(self) -> list[int]:
        ready = self.count >= self.warmup
        if ready.sum() < max(2, self.n // 2):
            return []
        med = float(np.median(self.ewma[ready]))
        if med <= 0:
            return []
        return [
            int(i)
            for i in range(self.n)
            if ready[i] and self.ewma[i] > self.threshold * med
        ]

    def plan(self) -> dict:
        s = self.stragglers()
        return {
            "stragglers": s,
            "action": "skip_token_turn" if s else "none",
        }
