"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, T_enc, D).  The encoder is bidirectional
self-attention with sinusoidal positions; the decoder is causal self-attn +
cross-attn with learned positions.  Decode caches: self K/V ring + the
encoder output projected to per-layer cross K/V once at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .attention import attend, update_cache
from .common import ParamFactory, layer_norm, sinusoidal_positions
from .transformer import ModelConfig


def _proj_init(pf, path, cfg, stacked: int):
    D, hd = cfg.d_model, cfg.hd
    fa = cfg.fsdp_axes
    L = (stacked,)
    pf.param(f"{path}/wq", L + (D, cfg.h_pad * hd), P(None, fa, "model"))
    pf.param(f"{path}/wk", L + (D, cfg.h_pad * hd), P(None, fa, "model"))
    pf.param(f"{path}/wv", L + (D, cfg.h_pad * hd), P(None, fa, "model"))
    pf.param(f"{path}/wo", L + (cfg.h_pad * hd, D), P(None, "model", fa))


def _mlp_init(pf, path, cfg, stacked: int):
    D, F = cfg.d_model, cfg.d_ff
    fa = cfg.fsdp_axes
    L = (stacked,)
    pf.param(f"{path}/w1", L + (D, F), P(None, fa, "model"))
    pf.param(f"{path}/b1", L + (F,), P(None, "model"), init="zeros")
    pf.param(f"{path}/w2", L + (F, D), P(None, "model", fa))
    pf.param(f"{path}/b2", L + (D,), P(None, None), init="zeros")


def _ln_init(pf, path, stacked: int, d: int):
    pf.param(f"{path}/w", (stacked, d), P(None, None), init="ones")
    pf.param(f"{path}/b", (stacked, d), P(None, None), init="zeros")


def _mha(p, cfg, xq, xkv=None, *, causal, cache=None, kv_len=None, q_offset=0):
    B, Sq, D = xq.shape
    hd = cfg.hd
    src = xq if xkv is None else xkv
    q = (xq @ p["wq"]).reshape(B, Sq, cfg.h_pad, hd)
    if cache is not None and "pos" in cache:
        # decode self-attention: append to ring
        k = (xq @ p["wk"]).reshape(B, Sq, cfg.h_pad, hd)
        v = (xq @ p["wv"]).reshape(B, Sq, cfg.h_pad, hd)
        ck, cv = update_cache(cache["k"], cache["v"], k, v, cache["pos"])
        out = attend(q, ck, cv, causal=True, q_offset=cache["pos"],
                     kv_len=cache["pos"] + Sq, chunk=cfg.attn_chunk)
        new_cache = {"k": ck, "v": cv, "pos": cache["pos"] + Sq}
    elif cache is not None:
        # cross-attention with precomputed K/V
        out = attend(q, cache["k"], cache["v"], causal=False,
                     chunk=cfg.attn_chunk)
        new_cache = cache
    else:
        k = (src @ p["wk"]).reshape(B, -1, cfg.h_pad, hd)
        v = (src @ p["wv"]).reshape(B, -1, cfg.h_pad, hd)
        out = attend(q, k, v, causal=causal, q_offset=q_offset,
                     chunk=cfg.attn_chunk)
        new_cache = None
    return out.reshape(B, Sq, cfg.h_pad * hd) @ p["wo"], new_cache


def _mlp(p, x):
    return jax.nn.gelu(x @ p["w1"] + p["b1"], approximate=True) @ p["w2"] + p["b2"]


class WhisperModel:
    """Config reuse: n_layers = decoder layers; encoder_layers mirrored."""

    def __init__(self, cfg: ModelConfig, mesh=None, encoder_seq: int = 1500):
        self.cfg = cfg
        self.mesh = mesh
        self.encoder_seq = encoder_seq

    def init(self, key, abstract: bool = False):
        cfg = self.cfg
        pf = ParamFactory(key, dtype=cfg.dtype, abstract=abstract)
        D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
        fa = cfg.fsdp_axes
        pf.param("embed", (cfg.vocab_pad, D), P("model", fa), scale=0.02)
        pf.param("pos_dec", (4096, D), P(None, None), scale=0.02)
        _proj_init(pf, "enc/attn", cfg, L)
        _mlp_init(pf, "enc/mlp", cfg, L)
        _ln_init(pf, "enc/ln1", L, D)
        _ln_init(pf, "enc/ln2", L, D)
        _proj_init(pf, "dec/self_attn", cfg, L)
        _proj_init(pf, "dec/cross_attn", cfg, L)
        _mlp_init(pf, "dec/mlp", cfg, L)
        _ln_init(pf, "dec/ln1", L, D)
        _ln_init(pf, "dec/ln2", L, D)
        _ln_init(pf, "dec/ln3", L, D)
        pf.param("final_ln/w", (D,), P(None), init="ones")
        pf.param("final_ln/b", (D,), P(None), init="zeros")
        return pf.params, pf.specs

    def encode(self, params, frames):
        """frames: (B, T_enc, D) precomputed frame embeddings (stub)."""
        cfg = self.cfg
        x = frames.astype(cfg.dtype) + sinusoidal_positions(
            frames.shape[1], cfg.d_model
        ).astype(cfg.dtype)

        def body(x, pl):
            h = layer_norm(x, pl["ln1"]["w"], pl["ln1"]["b"])
            a, _ = _mha(pl["attn"], cfg, h, causal=False)
            x = x + a
            h = layer_norm(x, pl["ln2"]["w"], pl["ln2"]["b"])
            return x + _mlp(pl["mlp"], h), None

        if cfg.layer_mode == "scan":
            x, _ = jax.lax.scan(body, x, params["enc"])
        else:
            for i in range(cfg.n_layers):
                x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc"]))
        return x

    def _decoder(self, params, tokens, enc_out, caches, pos0):
        cfg = self.cfg
        B, S = tokens.shape
        x = params["embed"][tokens].astype(cfg.dtype)
        pos = pos0 + jnp.arange(S)
        x = x + params["pos_dec"][pos][None].astype(cfg.dtype)

        def body(x, pl, cache):
            h = layer_norm(x, pl["ln1"]["w"], pl["ln1"]["b"])
            a, nc_self = _mha(pl["self_attn"], cfg, h, causal=True,
                              cache=None if cache is None else cache["self"],
                              q_offset=pos0)
            x = x + a
            h = layer_norm(x, pl["ln2"]["w"], pl["ln2"]["b"])
            if cache is None:
                a, _ = _mha(pl["cross_attn"], cfg, h, enc_out, causal=False)
                nc = None
            else:
                a, _ = _mha(pl["cross_attn"], cfg, h, causal=False,
                            cache=cache["cross"])
                nc = {"self": nc_self, "cross": cache["cross"]}
            x = x + a
            h = layer_norm(x, pl["ln3"]["w"], pl["ln3"]["b"])
            return x + _mlp(pl["mlp"], h), nc

        if cfg.layer_mode == "scan":
            def scan_body(x, inp):
                pl, cache = inp
                return body(x, pl, cache)

            x, new_caches = jax.lax.scan(scan_body, x, (params["dec"], caches))
        else:
            ncs = []
            for i in range(cfg.n_layers):
                pl = jax.tree.map(lambda a: a[i], params["dec"])
                ci = None if caches is None else jax.tree.map(
                    lambda a: a[i], caches
                )
                x, nc = body(x, pl, ci)
                ncs.append(nc)
            new_caches = (None if caches is None else
                          jax.tree.map(lambda *zs: jnp.stack(zs), *ncs))
        x = layer_norm(x, params["final_ln"]["w"], params["final_ln"]["b"])
        logits = (x @ params["embed"].T).astype(jnp.float32)
        if cfg.vocab_pad != cfg.vocab:
            logits = jnp.where(jnp.arange(cfg.vocab_pad) < cfg.vocab,
                               logits, -1e30)
        return logits, new_caches

    def loss_fn(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        logits, _ = self._decoder(params, batch["tokens"], enc_out, None, 0)
        labels = batch["labels"]
        mask = labels >= 0
        lab = jnp.clip(labels, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = jnp.where(mask, lse - gold, 0.0)
        return nll.sum() / jnp.maximum(mask.sum(), 1)

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        hd = cfg.hd
        L = cfg.n_layers
        z = lambda s: jnp.zeros(s, cfg.dtype)
        return {
            "self": {"k": z((L, batch, max_len, cfg.h_pad, hd)),
                     "v": z((L, batch, max_len, cfg.h_pad, hd)),
                     "pos": jnp.zeros((L,), jnp.int32)},
            "cross": {"k": z((L, batch, self.encoder_seq, cfg.h_pad, hd)),
                      "v": z((L, batch, self.encoder_seq, cfg.h_pad, hd))},
        }

    def prefill(self, params, frames, tokens):
        """Encode audio, precompute cross K/V, run decoder prefix."""
        cfg = self.cfg
        B = frames.shape[0]
        enc_out = self.encode(params, frames)
        hd = cfg.hd

        def cross_kv(pl):
            k = (enc_out @ pl["cross_attn"]["wk"]).reshape(
                B, -1, cfg.h_pad, hd
            )
            v = (enc_out @ pl["cross_attn"]["wv"]).reshape(
                B, -1, cfg.h_pad, hd
            )
            return k, v

        if cfg.layer_mode == "scan":
            _, (cks, cvs) = jax.lax.scan(
                lambda c, pl: (c, cross_kv(pl)), None, params["dec"]
            )
        else:
            outs = [cross_kv(jax.tree.map(lambda a: a[i], params["dec"]))
                    for i in range(cfg.n_layers)]
            cks = jnp.stack([o[0] for o in outs])
            cvs = jnp.stack([o[1] for o in outs])

        caches = self.init_cache(B, tokens.shape[1] + 1)
        caches["cross"] = {"k": cks, "v": cvs}
        logits, caches = self._decoder(params, tokens, None, caches, 0)
        return logits[:, -1], caches

    def forward_cached(self, params, tokens, caches):
        pos0 = caches["self"]["pos"][0]
        logits, new_caches = self._decoder(params, tokens, None, caches, pos0)
        return logits[:, -1], new_caches
