"""Attention math: GQA with q-chunked (flash-style) softmax in pure jnp.

This is the XLA path used for lowering/roofline (the Pallas flash kernel in
``repro.kernels`` is the TPU target and is validated against this).  Chunking
the query axis bounds the live score tensor to (B, H, chunk, S_kv) — without
it the 32k-prefill cells would materialize petabyte-scale S×S score tensors.

Supports: causal masking with offset (prefill continuation / decode), sliding
windows (gemma2 local layers), logit soft-capping (gemma2), GQA without
materializing repeated KV heads, and explicit kv validity lengths (decode
against a partially-filled cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attend(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    logit_cap: float | None = None,
    q_offset=0,
    kv_len=None,
    chunk: int = 512,
    mesh=None,
    da=None,
    kv_seq_shard: bool = False,
):
    """q: (B, Sq, H, hd); k, v: (B, Skv, Hkv, hd); returns (B, Sq, H, hd).

    H must be a multiple of Hkv (GQA).  KV heads are expanded to H before the
    score einsum so the head axis stays shardable over the TP mesh axis even
    when Hkv < TP (the expansion is free under sharding: each device
    materializes only its local heads).  ``q_offset`` is the absolute
    position of q[0] (scalar or (B,)); ``kv_len`` masks unwritten cache
    slots.  mesh/da: activation-sharding pins (batch over data axes, heads
    over model) — without them GSPMD drops batch sharding through the
    q-chunk scan.
    """
    from .sharding import pin

    B, Sq, H, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    assert H % Hkv == 0, (H, Hkv)
    if kv_seq_shard:
        # flash-decode layout: KV stays sharded along its SEQUENCE dim (the
        # cache's resident layout when kv heads don't divide TP); q is
        # replicated over `model`; every shard computes all heads over its
        # seq slice; softmax over the sharded axis and the p·V contraction
        # reduce with small psums instead of all-gathering the cache.
        k = pin(k, mesh, da, "model", None, None)
        v = pin(v, mesh, da, "model", None, None)
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if kv_seq_shard:
        k = pin(k, mesh, da, "model", None, None)
        v = pin(v, mesh, da, "model", None, None)
        q = pin(q, mesh, da, None, None, None)
    else:
        k = pin(k, mesh, da, None, "model", None)
        v = pin(v, mesh, da, None, "model", None)
    scale = hd ** -0.5
    orig_dtype = q.dtype

    kv_pos = jnp.arange(Skv)
    q_off = jnp.asarray(q_offset)
    q_off = q_off.reshape((-1, 1)) if q_off.ndim else q_off  # (B,1) or scalar

    def block(q_blk, blk_idx):
        # q_blk: (B, C, H, hd)
        C = q_blk.shape[1]
        s = jnp.einsum("bchd,bshd->bhcs", q_blk.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        if logit_cap is not None:
            s = jnp.tanh(s / logit_cap) * logit_cap
        q_pos = q_off + blk_idx * C + jnp.arange(C)  # (B,C) or (C,)
        if q_pos.ndim == 1:
            q_pos = q_pos[None, :]
        m = jnp.ones((B, C, Skv), bool)
        if causal:
            m &= q_pos[:, :, None] >= kv_pos[None, None, :]
        if window is not None:
            m &= (q_pos[:, :, None] - kv_pos[None, None, :]) < window
        if kv_len is not None:
            kl = jnp.asarray(kv_len).reshape((-1, 1, 1))
            m &= kv_pos[None, None, :] < kl
        s = jnp.where(m[:, None, :, :], s, -1e30)
        if kv_seq_shard:
            s = pin(s, mesh, da, None, None, "model")
        else:
            s = pin(s, mesh, da, "model", None, None)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhcs,bshd->bchd", p, v.astype(jnp.float32))
        if kv_seq_shard:
            return pin(o.astype(orig_dtype), mesh, da, None, None, None)
        return pin(o.astype(orig_dtype), mesh, da, None, "model", None)

    if Sq <= chunk:
        return block(q, 0)

    assert Sq % chunk == 0, (Sq, chunk)
    n_blocks = Sq // chunk
    q_blocks = q.reshape(B, n_blocks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    q_blocks = pin(q_blocks, mesh, None, da, None, "model", None)

    def scan_body(_, inp):
        q_blk, idx = inp
        q_blk = pin(q_blk, mesh, da, None, "model", None)
        return None, block(q_blk, idx)

    _, out = jax.lax.scan(scan_body, None, (q_blocks, jnp.arange(n_blocks)))
    out = pin(out, mesh, None, da, None, "model", None)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def update_cache(cache_k, cache_v, k_new, v_new, at):
    """Write new K/V at position ``at`` (scalar step index) — decode path."""
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, at, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, at, axis=1)
    return cache_k, cache_v
