"""Shared model building blocks: norms, RoPE (incl. M-RoPE), init helpers,
and the sharding-spec convention.

Sharding convention (see DESIGN.md §5): every parameter is created through
``param(key, shape, spec)`` which records a ``PartitionSpec`` in a parallel
tree.  Axis names: "model" = tensor parallel, FSDP = ("pod","data") on a
weight's major input dim when cfg.fsdp is set.  Specs are consumed by the
launcher to build in_shardings for the dry-run and real runs.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass
class ParamFactory:
    """Collects (params, specs) trees while modules declare parameters.

    ``abstract=True`` creates ShapeDtypeStructs instead of arrays — used by
    the dry-run to build full-size configs without allocating a single byte.
    """

    key: jax.Array
    dtype: jnp.dtype = jnp.float32
    abstract: bool = False

    def __post_init__(self):
        self.params: dict = {}
        self.specs: dict = {}

    def _split(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def param(self, path: str, shape: tuple, spec: P, scale: float | None = None,
              init: str = "normal"):
        """Create one parameter at a '/'-separated path."""
        if self.abstract:
            val = jax.ShapeDtypeStruct(shape, self.dtype)
        elif init == "zeros":
            val = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            val = jnp.ones(shape, self.dtype)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            s = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
            val = (jax.random.normal(self._split(), shape, self.dtype) * s)
        d_p, d_s = self.params, self.specs
        parts = path.split("/")
        for k in parts[:-1]:
            d_p = d_p.setdefault(k, {})
            d_s = d_s.setdefault(k, {})
        d_p[parts[-1]] = val
        d_s[parts[-1]] = spec
        return val


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight + bias).astype(dtype)


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap


# --- rotary embeddings ------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_thw, sections: Sequence[int], theta: float = 1e6):
    """Multimodal RoPE (Qwen2-VL): positions_thw (3, ..., S) gives temporal /
    height / width indices; rotary sections split the half-dim into t/h/w
    bands (sections sum to hd/2)."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    bands = []
    start = 0
    for sec, pos in zip(sections, positions_thw):
        f = freqs[start : start + sec]
        bands.append(pos[..., :, None, None].astype(jnp.float32) * f)
        start += sec
    angles = jnp.concatenate(bands, axis=-1)  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / d)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, jnp.float32)


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m
