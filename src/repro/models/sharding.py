"""Activation sharding pins.

GSPMD propagation through scans/reshapes can drop the batch sharding of
activations (observed: full-global-batch f32 logits gathered per device).
Production JAX stacks pin activation shardings at block boundaries; ``pin``
does that, sanitizing per-dim (a dim that doesn't divide its mesh axes is
left unsharded — e.g. batch=1 long-context decode).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    out = 1
    for n in names:
        out *= mesh.shape[n]
    return out


def pin(x, mesh, *spec):
    """with_sharding_constraint(x, P(*spec)) with per-dim divisibility checks.

    spec entries: None | axis-name | tuple of axis names.  Entries whose mesh
    size doesn't divide the dim are dropped to None.
    """
    if mesh is None or x is None:
        return x
    assert len(spec) == x.ndim, (spec, x.shape)
    fixed = tuple(
        e if e is not None and d % _axis_size(mesh, e) == 0 else None
        for e, d in zip(spec, x.shape)
    )
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


def data_axes_of(mesh):
    if mesh is None:
        return None
    return tuple(a for a in mesh.axis_names if a != "model")
