"""RWKV6 "Finch" block (data-dependent decay) in pure JAX.

Time-mix uses the WKV6 recurrence with per-channel data-dependent decay
w_t = exp(-exp(w_base + lora(x))) — the architecture's signature feature —
and a time-first bonus u.  The jnp path runs the exact per-step recurrence
under lax.scan (the oracle); the Pallas kernel in
``repro.kernels.rwkv6_wkv`` implements the chunked form for TPU.

Decode state is O(1): (last token for time-mix shift, last token for
channel-mix shift, WKV state (H, hd, hd)) — which is why rwkv6 runs the
500k-context cell that quadratic-attention archs skip.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import rms_norm


@dataclasses.dataclass(frozen=True)
class RWKV6Config:
    d_model: int
    head_dim: int = 64
    d_ff: int = 0  # channel-mix hidden
    lora_rank: int = 64

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim


def token_shift(x, last):
    """x: (B,S,D); last: (B,D) previous token (decode continuation).
    Returns x shifted right by one along S with ``last`` filled in."""
    return jnp.concatenate([last[:, None], x[:, :-1]], axis=1)


def wkv6_scan(r, k, v, w, u):
    """Exact WKV6 recurrence.

    r,k,v: (B,S,H,hd); w: (B,S,H,hd) per-step decay in (0,1);
    u: (H,hd) bonus.  Returns (y (B,S,H,hd), final state (B,H,hd,hd)).
    State S[i,j]: key-dim i, value-dim j.
    """
    B, S, H, hd = r.shape
    state0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(st, t):
        rt, kt, vt, wt = rf[:, t], kf[:, t], vf[:, t], wf[:, t]  # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", rt, st + uf[..., :, None] * kv)
        st = wt[..., :, None] * st + kv
        return st, y

    state, ys = jax.lax.scan(step, state0, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), state


def wkv6_step(r1, k1, v1, w1, u, state):
    """One decode step: r1..w1 (B,H,hd); state (B,H,hd,hd)."""
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r1, k1, v1, w1))
    kv = kf[..., :, None] * vf[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj", rf, state + u.astype(jnp.float32)[..., :, None] * kv)
    state = wf[..., :, None] * state + kv
    return y.astype(r1.dtype), state


def time_mix(p, x, cfg: RWKV6Config, last, wkv_state):
    """x: (B,S,D) → (out, (new_last, new_state))."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    xp = token_shift(x, last)

    def lerp(i):
        return x + (xp - x) * p["mu"][i]

    xr, xk, xv, xg, xw = (lerp(i) for i in range(5))
    # data-dependent decay (the Finch contribution)
    lora = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]  # (B,S,D)
    w = jnp.exp(-jnp.exp(p["w_base"].astype(jnp.float32)
                         + lora.astype(jnp.float32)))  # (B,S,D) in (0,1)

    r = (xr @ p["wr"]).reshape(B, S, H, hd)
    k = (xk @ p["wk"]).reshape(B, S, H, hd)
    v = (xv @ p["wv"]).reshape(B, S, H, hd)
    g = xg @ p["wg"]
    wr = w.reshape(B, S, H, hd)

    if S == 1 and wkv_state is not None:
        y1, new_state = wkv6_step(
            r[:, 0], k[:, 0], v[:, 0], wr[:, 0], p["u"], wkv_state
        )
        y = y1[:, None]
    else:
        y, new_state = wkv6_scan(r, k, v, wr, p["u"])
        if wkv_state is not None:
            # continuation decode-prefill not used in training; state resets
            pass

    y = y.reshape(B, S, D)
    y = rms_norm(y, p["ln_x"]) * jax.nn.silu(g)
    return y @ p["wo"], (x[:, -1], new_state)


def channel_mix(p, x, last):
    xp = token_shift(x, last)
    xk = x + (xp - x) * p["mu_k"]
    xr = x + (xp - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr_g"]) * (k @ p["wv"]), x[:, -1]


def rwkv6_block(p, x, cfg: RWKV6Config, cache=None):
    """Full layer: ln → time-mix → ln → channel-mix (pre-norm residual).
    cache: (tm_last, cm_last, wkv_state) or None."""
    tm_last = cache[0] if cache is not None else jnp.zeros(
        (x.shape[0], cfg.d_model), x.dtype
    )
    cm_last = cache[1] if cache is not None else jnp.zeros(
        (x.shape[0], cfg.d_model), x.dtype
    )
    wkv_state = cache[2] if cache is not None else None

    h = rms_norm(x, p["ln1"])
    att, (new_tm, new_state) = time_mix(p["tm"], h, cfg, tm_last, wkv_state)
    x = x + att
    h = rms_norm(x, p["ln2"])
    ffn, new_cm = channel_mix(p["cm"], h, cm_last)
    x = x + ffn
    return x, (new_tm, new_cm, new_state)


def init_rwkv6_params(pf, path: str, cfg: RWKV6Config, n_layers: int, fsdp_axes):
    from jax.sharding import PartitionSpec as P

    L = (n_layers,)
    D, r = cfg.d_model, cfg.lora_rank
    pf.param(f"{path}/ln1", L + (D,), P(None, None), init="zeros")
    pf.param(f"{path}/ln2", L + (D,), P(None, None), init="zeros")
    tm = f"{path}/tm"
    pf.param(f"{tm}/mu", L + (5, D), P(None, None, None), init="zeros")
    pf.param(f"{tm}/w_lora_a", L + (D, r), P(None, fsdp_axes, None))
    pf.param(f"{tm}/w_lora_b", L + (r, D), P(None, None, None), init="zeros")
    pf.param(f"{tm}/w_base", L + (D,), P(None, None), init="zeros")
    pf.param(f"{tm}/u", L + (cfg.n_heads, cfg.head_dim), P(None, "model", None),
             init="zeros")
    for n in ("wr", "wk", "wv", "wg"):
        pf.param(f"{tm}/{n}", L + (D, D), P(None, fsdp_axes, "model"))
    pf.param(f"{tm}/ln_x", L + (D,), P(None, "model"), init="zeros")
    pf.param(f"{tm}/wo", L + (D, D), P(None, "model", fsdp_axes))
    cm = f"{path}/cm"
    pf.param(f"{cm}/mu_k", L + (D,), P(None, None), init="zeros")
    pf.param(f"{cm}/mu_r", L + (D,), P(None, None), init="zeros")
    pf.param(f"{cm}/wk", L + (D, cfg.d_ff), P(None, fsdp_axes, "model"))
    pf.param(f"{cm}/wr_g", L + (D, D), P(None, fsdp_axes, None))
    pf.param(f"{cm}/wv", L + (cfg.d_ff, D), P(None, "model", fsdp_axes))
