"""Unified decoder-LM skeleton for the assigned architectures.

One config dataclass + one model class covers the dense / moe / vlm / ssm /
hybrid families (whisper's enc-dec lives in whisper.py).  Layers are stacked
(L, ...) and executed with lax.scan (O(1)-in-depth HLO — required for the
512-device dry-run) or unrolled (roofline mode, exact cost_analysis).
gemma2's local/global alternation is handled by scanning over PAIRS of
layers so the window stays a static property.

TP sharding follows Megatron conventions on the ``model`` axis with GSPMD
inserting the collectives; q-heads are padded to a multiple of the TP degree
and KV heads are replicated when they don't divide it (DESIGN.md §5 — the
HLO/MODEL FLOP ratio in EXPERIMENTS.md accounts for the padding).  Optional
FSDP shards every weight's major dim over ("pod","data") — required to fit
the 1T-param MoE.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .attention import attend, update_cache
from .common import (
    ParamFactory,
    apply_mrope,
    apply_rope,
    pad_to_multiple,
    rms_norm,
    softcap,
)
from .ffn import gated_mlp, moe_block
from .sharding import data_axes_of, pin
from .mamba2 import Mamba2Config, init_mamba2_params, mamba2_forward
from .rwkv6 import RWKV6Config, init_rwkv6_params, rwkv6_block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 → d_model // n_heads
    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: int | None = None  # sliding-window size (gemma2 local layers)
    alt_window: bool = False  # alternate local/global layers (gemma2)
    attn_softcap: float | None = None
    final_softcap: float | None = None
    act: str = "silu"
    post_norms: bool = False  # gemma2 post-layer norms
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    # ssm / hybrid
    ssm_state: int = 0
    attn_every: int = 0  # zamba2: shared attn after every k mamba layers
    # vlm
    mrope_sections: tuple | None = None
    # execution
    dtype: Any = jnp.bfloat16
    remat: str = "none"  # none | full | dots
    layer_mode: str = "scan"  # scan | unroll
    fsdp: bool = False
    tp: int = 1  # TP degree used for head padding / sharding decisions
    attn_chunk: int = 512
    moe_shard_map: bool = True
    capacity_factor: float = 1.25  # MoE dispatch capacity (E/top_k ⇒ no drops)

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def h_pad(self) -> int:
        return pad_to_multiple(self.n_heads, self.tp)

    @property
    def kv_pad(self) -> int:
        kv = self.n_kv_heads
        while self.h_pad % kv != 0:
            kv += 1
        return kv

    @property
    def kv_sharded(self) -> bool:
        return self.kv_pad % self.tp == 0

    @property
    def vocab_pad(self) -> int:
        """Embedding rows padded so the vocab-parallel shard divides TP
        (whisper's 51865 → 51872); logits over padded ids are masked."""
        return pad_to_multiple(self.vocab, self.tp)

    @property
    def fsdp_axes(self):
        return ("pod", "data") if self.fsdp else None

    def n_params(self) -> float:
        """Analytic parameter count (unpadded), for MODEL_FLOPS."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        attn = D * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * D
        if self.family == "ssm":  # rwkv6
            tm = 4 * D * D + 2 * D * 64 + D * D
            cm = 2 * D * F + D * D
            return L * (tm + cm) + 2 * V * D
        if self.family == "hybrid":
            m = Mamba2Config(D, self.ssm_state)
            mamba = D * m.in_dim + m.d_inner * D
            n_attn = L // (self.attn_every + 1)
            n_mamba = L - n_attn
            return n_mamba * mamba + (attn + 3 * D * F) + 2 * V * D
        ffn = 3 * D * F
        if self.n_experts:
            ffn = self.n_experts * 3 * D * F + D * self.n_experts
        return L * (attn + ffn) + (V * D if self.tie_embeddings else 2 * V * D)

    def n_active_params(self) -> float:
        if not self.n_experts:
            return self.n_params()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        hd = self.hd
        attn = D * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * D
        ffn = self.top_k * 3 * D * F + D * self.n_experts
        return L * (attn + ffn) + 2 * self.vocab * D


# ---------------------------------------------------------------------------


def _spec(L_stacked: bool, *rest) -> P:
    return P(None, *rest) if L_stacked else P(*rest)


def _attn_param_init(pf: ParamFactory, path: str, cfg: ModelConfig,
                     stacked: int | None):
    D, hd = cfg.d_model, cfg.hd
    fa = cfg.fsdp_axes
    kv_spec = "model" if cfg.kv_sharded else None
    L = (stacked,) if stacked else ()
    st = stacked is not None and stacked > 0
    pf.param(f"{path}/wq", L + (D, cfg.h_pad * hd), _spec(st, fa, "model"))
    pf.param(f"{path}/wk", L + (D, cfg.kv_pad * hd), _spec(st, fa, kv_spec))
    pf.param(f"{path}/wv", L + (D, cfg.kv_pad * hd), _spec(st, fa, kv_spec))
    pf.param(f"{path}/wo", L + (cfg.h_pad * hd, D), _spec(st, "model", fa))
    if cfg.qkv_bias:
        pf.param(f"{path}/bq", L + (cfg.h_pad * hd,), _spec(st, "model"),
                 init="zeros")
        pf.param(f"{path}/bk", L + (cfg.kv_pad * hd,), _spec(st, kv_spec),
                 init="zeros")
        pf.param(f"{path}/bv", L + (cfg.kv_pad * hd,), _spec(st, kv_spec),
                 init="zeros")
    if cfg.qk_norm:
        pf.param(f"{path}/q_norm", L + (hd,), _spec(st, None), init="zeros")
        pf.param(f"{path}/k_norm", L + (hd,), _spec(st, None), init="zeros")


def _attn_apply(p, x, cfg: ModelConfig, *, positions, cache=None, window=None,
                mesh=None):
    """x: (B,S,D) → (out, new_cache).  positions: (B,S) or (3,B,S) M-RoPE."""
    da = data_axes_of(mesh)
    B, S, D = x.shape
    hd = cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.h_pad, hd)
    k = k.reshape(B, S, cfg.kv_pad, hd)
    v = v.reshape(B, S, cfg.kv_pad, hd)
    kv_tp = "model" if cfg.kv_sharded else None
    q = pin(q, mesh, da, None, "model", None)
    k = pin(k, mesh, da, None, kv_tp, None)
    v = pin(v, mesh, da, None, kv_tp, None)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        pos_1d = positions[0]
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        pos_1d = positions

    if cache is None:
        out = attend(q, k, v, causal=True, window=window,
                     logit_cap=cfg.attn_softcap, chunk=cfg.attn_chunk,
                     mesh=mesh, da=da)
        new_cache = None
    else:
        pos = cache["pos"]  # scalar write offset
        ck, cv = update_cache(cache["k"], cache["v"], k, v, pos)
        # kv-replicated archs keep the cache sharded along SEQ over `model`;
        # decode then uses the flash-decode layout (q replicated over model,
        # partial softmax per seq shard, small psums) instead of
        # all-gathering the cache — §Perf iteration I-C1.
        seq_shard = (not cfg.kv_sharded) and S == 1
        if seq_shard:
            ck = pin(ck, mesh, da, "model", None, None)
            cv = pin(cv, mesh, da, "model", None, None)
        out = attend(
            q, ck, cv, causal=True, window=window, logit_cap=cfg.attn_softcap,
            q_offset=pos_1d[:, 0], kv_len=pos + S, chunk=cfg.attn_chunk,
            mesh=mesh, da=da, kv_seq_shard=seq_shard,
        )
        new_cache = {"k": ck, "v": cv, "pos": pos + S}
    out = out.reshape(B, S, cfg.h_pad * hd) @ p["wo"]
    return pin(out, mesh, da, None, None), new_cache


def _moe_apply(p, x, cfg: ModelConfig, mesh):
    """x: (B,S,D) → (out, aux_loss); shard_map grouped-GEMM dispatch."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    if (mesh is None or not cfg.moe_shard_map
            or math.prod(mesh.devices.shape) == 1):
        out, aux = moe_block(xt, p["router"], p["w_gate"], p["w_up"],
                             p["w_down"], top_k=cfg.top_k, act=cfg.act,
                             capacity_factor=cfg.capacity_factor)
        return out.reshape(B, S, D), aux

    data_axes = tuple(a for a in mesh.axis_names if a != "model")

    def local(xt, rw, wg, wu, wd):
        out, aux = moe_block(xt, rw, wg, wu, wd, top_k=cfg.top_k, act=cfg.act,
                             capacity_factor=cfg.capacity_factor)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, "model")
        return out, aux

    out, aux = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(data_axes, None), P(None, None), P(None, None, "model"),
                  P(None, None, "model"), P(None, "model", None)),
        out_specs=(P(data_axes, None), P()),
        check_vma=False,
    )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out.reshape(B, S, D), aux


class DecoderLM:
    """Families: dense, moe, vlm, ssm (rwkv6), hybrid (zamba2)."""

    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        if cfg.family == "hybrid":
            assert cfg.attn_every > 0
            per = cfg.attn_every + 1  # k mamba blocks + 1 shared-attn use
            self.n_groups = cfg.n_layers // per
            assert self.n_groups >= 1, (cfg.n_layers, per)
            self.n_tail = cfg.n_layers - self.n_groups * per
            m_hd = 64
            while (2 * cfg.d_model) % m_hd:  # reduced configs: keep integral
                m_hd //= 2
            self.mcfg = Mamba2Config(cfg.d_model, cfg.ssm_state,
                                     head_dim=m_hd)
        if cfg.family == "ssm":
            self.rcfg = RWKV6Config(cfg.d_model, d_ff=cfg.d_ff)
        if cfg.alt_window:
            assert cfg.n_layers % 2 == 0, "alt_window needs even layer count"

    # -- parameters ----------------------------------------------------------
    def init(self, key, abstract: bool = False) -> tuple[dict, dict]:
        cfg = self.cfg
        pf = ParamFactory(key, dtype=cfg.dtype, abstract=abstract)
        D, V = cfg.d_model, cfg.vocab
        fa = cfg.fsdp_axes
        pf.param("embed", (cfg.vocab_pad, D), P("model", fa), scale=0.02)
        if not cfg.tie_embeddings:
            pf.param("lm_head", (D, cfg.vocab_pad), P(fa, "model"))
        pf.param("final_norm", (D,), P(None), init="zeros")

        nL = cfg.n_layers
        L = (nL,)
        if cfg.family == "ssm":
            init_rwkv6_params(pf, "layers", self.rcfg, nL, fa)
        elif cfg.family == "hybrid":
            init_mamba2_params(pf, "groups/mamba", self.mcfg,
                               self.n_groups * cfg.attn_every, fa)
            pf.param("groups/ln_attn", (self.n_groups, D), P(None, None),
                     init="zeros")
            _attn_param_init(pf, "shared_attn", cfg, None)
            pf.param("shared_ln", (D,), P(None), init="zeros")
            pf.param("shared_mlp/w_gate", (D, cfg.d_ff), P(fa, "model"))
            pf.param("shared_mlp/w_up", (D, cfg.d_ff), P(fa, "model"))
            pf.param("shared_mlp/w_down", (cfg.d_ff, D), P("model", fa))
            if self.n_tail:
                init_mamba2_params(pf, "tail", self.mcfg, self.n_tail, fa)
        else:
            pf.param("layers/ln1", L + (D,), P(None, None), init="zeros")
            pf.param("layers/ln2", L + (D,), P(None, None), init="zeros")
            if cfg.post_norms:
                pf.param("layers/ln1_post", L + (D,), P(None, None), init="zeros")
                pf.param("layers/ln2_post", L + (D,), P(None, None), init="zeros")
            _attn_param_init(pf, "layers/attn", cfg, nL)
            if cfg.n_experts:
                pf.param("layers/mlp/router", L + (D, cfg.n_experts),
                         P(None, None, None), scale=0.02)
                pf.param("layers/mlp/w_gate", L + (cfg.n_experts, D, cfg.d_ff),
                         P(None, None, fa, "model"))
                pf.param("layers/mlp/w_up", L + (cfg.n_experts, D, cfg.d_ff),
                         P(None, None, fa, "model"))
                pf.param("layers/mlp/w_down", L + (cfg.n_experts, cfg.d_ff, D),
                         P(None, None, "model", fa))
            else:
                pf.param("layers/mlp/w_gate", L + (D, cfg.d_ff),
                         P(None, fa, "model"))
                pf.param("layers/mlp/w_up", L + (D, cfg.d_ff),
                         P(None, fa, "model"))
                pf.param("layers/mlp/w_down", L + (cfg.d_ff, D),
                         P(None, "model", fa))
        return pf.params, pf.specs

    # -- block bodies ----------------------------------------------------------
    def _dense_block(self, pl, x, positions, cache, window):
        cfg = self.cfg
        h = rms_norm(x, pl["ln1"])
        attn_out, new_cache = _attn_apply(
            pl["attn"], h, cfg, positions=positions, cache=cache,
            window=window, mesh=self.mesh,
        )
        if cfg.post_norms:
            attn_out = rms_norm(attn_out, pl["ln1_post"])
        x = x + attn_out
        h = rms_norm(x, pl["ln2"])
        aux = jnp.zeros((), jnp.float32)
        if cfg.n_experts:
            mlp_out, aux = _moe_apply(pl["mlp"], h, cfg, self.mesh)
        else:
            mlp_out = gated_mlp(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"],
                                pl["mlp"]["w_down"], act=cfg.act)
        if cfg.post_norms:
            mlp_out = rms_norm(mlp_out, pl["ln2_post"])
        return x + mlp_out, new_cache, aux

    def _maybe_remat(self, fn):
        cfg = self.cfg
        if cfg.remat == "none":
            return fn
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        return jax.checkpoint(fn, policy=policy)

    # -- forward -------------------------------------------------------------
    def _backbone(self, params, x, positions, caches=None):
        """x: (B,S,D) embeddings → (final hidden, new caches, aux loss)."""
        cfg = self.cfg
        training = caches is None

        if cfg.family == "ssm":
            def body(x, pl, cache):
                y, new_cache = rwkv6_block(pl, x, self.rcfg, cache)
                return y, (None if training else new_cache), jnp.zeros((), jnp.float32)

            return self._stack_loop(body, x, params["layers"], caches,
                                    cfg.n_layers)
        if cfg.family == "hybrid":
            return self._hybrid_backbone(params, x, positions, caches)

        if cfg.alt_window:
            # pair the layers: even index → local window, odd → global
            lp = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers // 2, 2) + a.shape[1:]),
                params["layers"],
            )
            cc = (None if training else jax.tree.map(
                lambda a: a.reshape((cfg.n_layers // 2, 2) + a.shape[1:]), caches
            ))

            def body(x, pl, cache):
                aux = jnp.zeros((), jnp.float32)
                new_cs = []
                for j, win in enumerate((cfg.window, None)):
                    plj = jax.tree.map(lambda a: a[j], pl)
                    cj = None if cache is None else jax.tree.map(
                        lambda a: a[j], cache
                    )
                    x, nc, a = self._dense_block(plj, x, positions, cj, win)
                    aux += a
                    new_cs.append(nc)
                nc = (None if training else
                      jax.tree.map(lambda *zs: jnp.stack(zs), *new_cs))
                return x, nc, aux

            x, nc, aux = self._stack_loop(body, x, lp, cc, cfg.n_layers // 2)
            if not training:
                # un-pair back to flat (L, ...) cache layout
                nc = jax.tree.map(
                    lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), nc
                )
            return x, nc, aux

        def body(x, pl, cache):
            return self._dense_block(pl, x, positions, cache, cfg.window)

        return self._stack_loop(body, x, params["layers"], caches, cfg.n_layers)

    def _stack_loop(self, body, x, layer_params, caches, n: int):
        """Run ``body(x, layer_slice, cache_slice) -> (x, new_cache, aux)``
        over stacked layers via scan or unroll."""
        cfg = self.cfg
        da = data_axes_of(self.mesh)

        def entry(x, pl, cache):
            # The barrier stops XLA hoisting per-layer bf16→f32 converts of
            # the saved residual out of the backward loop — without it the
            # whole (L,B,S,D) stack materializes again in f32 (observed:
            # +14 GiB/device on qwen3 train_4k).
            x = jax.lax.optimization_barrier(x)
            return body(pin(x, self.mesh, da, None, None), pl, cache)

        fn = self._maybe_remat(entry)

        if cfg.layer_mode == "scan":
            def scan_body(carry, inp):
                x, aux = carry
                pl, cache = inp
                x, nc, a = fn(x, pl, cache)
                return (x, aux + a), nc

            (x, aux), new_caches = jax.lax.scan(
                scan_body, (x, jnp.zeros((), jnp.float32)),
                (layer_params, caches),
            )
            return x, new_caches, aux

        aux = jnp.zeros((), jnp.float32)
        ncs = []
        for i in range(n):
            pl = jax.tree.map(lambda a: a[i], layer_params)
            ci = None if caches is None else jax.tree.map(lambda a: a[i], caches)
            x, nc, a = fn(x, pl, ci)
            aux += a
            ncs.append(nc)
        new_caches = (None if caches is None else
                      jax.tree.map(lambda *zs: jnp.stack(zs), *ncs))
        return x, new_caches, aux

    def _hybrid_backbone(self, params, x, positions, caches):
        """zamba2: groups of (attn_every mamba blocks + 1 shared-attn use),
        plus a mamba tail.  Shared attention/MLP weights are reused (weight
        tying) but every use has its own KV cache."""
        cfg = self.cfg
        per = cfg.attn_every
        training = caches is None
        g = params["groups"]
        mamba_stacked = jax.tree.map(
            lambda a: a.reshape((self.n_groups, per) + a.shape[1:]), g["mamba"]
        )

        def group_body(x, pl, cache):
            pm, ln_attn = pl
            mc, ac = (None, None) if cache is None else cache
            new_mc = []
            for j in range(per):
                pmj = jax.tree.map(lambda a: a[j], pm)
                mcj = None if mc is None else jax.tree.map(lambda a: a[j], mc)
                h = rms_norm(x, pmj["ln"])
                y, c = mamba2_forward(pmj, h, self.mcfg, cache=mcj)
                x = x + y
                new_mc.append(c)
            h = rms_norm(x, ln_attn)
            attn_out, new_ac = _attn_apply(
                params["shared_attn"], h, cfg, positions=positions, cache=ac,
                mesh=self.mesh,
            )
            x = x + attn_out
            h = rms_norm(x, params["shared_ln"])
            x = x + gated_mlp(h, params["shared_mlp"]["w_gate"],
                              params["shared_mlp"]["w_up"],
                              params["shared_mlp"]["w_down"], act=cfg.act)
            if training:
                return x, None, jnp.zeros((), jnp.float32)
            new_mc = jax.tree.map(lambda *zs: jnp.stack(zs), *new_mc)
            return x, (new_mc, new_ac), jnp.zeros((), jnp.float32)

        group_caches = (None if training else
                        (caches["mamba"], caches["attn"]))
        x, new_gc, _ = self._stack_loop(
            group_body, x, (mamba_stacked, g["ln_attn"]), group_caches,
            self.n_groups,
        )

        new_tail = None
        if self.n_tail:
            def tail_body(x, pl, cache):
                h = rms_norm(x, pl["ln"])
                y, c = mamba2_forward(pl, h, self.mcfg, cache=cache)
                return x + y, (None if training else c), jnp.zeros((), jnp.float32)

            x, new_tail, _ = self._stack_loop(
                tail_body, x, params["tail"],
                None if training else caches["tail"], self.n_tail,
            )

        new_caches = None
        if not training:
            new_caches = {"mamba": new_gc[0], "attn": new_gc[1],
                          "tail": new_tail}
        return x, new_caches, jnp.zeros((), jnp.float32)

    # -- public entry points ---------------------------------------------------
    def _embed(self, params, tokens, vision_embeds=None, vision_mask=None):
        x = params["embed"][tokens] * (
            math.sqrt(self.cfg.d_model) if self.cfg.post_norms else 1.0
        )
        if vision_embeds is not None:
            # scatter precomputed patch embeddings over masked positions
            n_img = vision_embeds.shape[1]
            idx = jnp.cumsum(vision_mask.astype(jnp.int32), axis=1) - 1
            idx = jnp.clip(idx, 0, n_img - 1)
            img = jnp.take_along_axis(vision_embeds, idx[..., None], axis=1)
            x = jnp.where(vision_mask[..., None], img.astype(x.dtype), x)
        x = x.astype(self.cfg.dtype)
        return pin(x, self.mesh, data_axes_of(self.mesh), None, None)

    def _logits(self, params, h):
        cfg = self.cfg
        h = rms_norm(h, params["final_norm"])
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (h @ w).astype(jnp.float32)
        if cfg.final_softcap:
            logits = softcap(logits, cfg.final_softcap)
        if cfg.vocab_pad != cfg.vocab:
            logits = jnp.where(jnp.arange(cfg.vocab_pad) < cfg.vocab,
                               logits, -1e30)
        return pin(logits, self.mesh, data_axes_of(self.mesh), None, "model")

    def loss_fn(self, params, batch):
        """batch: tokens (B,S) int32, labels (B,S) int32 (-1 = masked),
        optional positions / vision_embeds / vision_mask."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = self._embed(params, tokens, batch.get("vision_embeds"),
                        batch.get("vision_mask"))
        h, _, aux = self._backbone(params, x, positions, caches=None)
        logits = self._logits(params, h)
        labels = batch["labels"]
        mask = labels >= 0
        lab = jnp.clip(labels, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = jnp.where(mask, lse - gold, 0.0)
        loss = nll.sum() / jnp.maximum(mask.sum(), 1)
        return loss + 0.01 * aux

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Any:
        cfg = self.cfg
        hd = cfg.hd
        kv_shape = (batch, max_len, cfg.kv_pad, hd)

        def attn_cache(n: int | None):
            lead = (n,) if n else ()
            return {
                "k": jnp.zeros(lead + kv_shape, cfg.dtype),
                "v": jnp.zeros(lead + kv_shape, cfg.dtype),
                "pos": jnp.zeros(lead, jnp.int32),
            }

        if cfg.family == "ssm":
            r = self.rcfg
            return (
                jnp.zeros((cfg.n_layers, batch, cfg.d_model), cfg.dtype),
                jnp.zeros((cfg.n_layers, batch, cfg.d_model), cfg.dtype),
                jnp.zeros((cfg.n_layers, batch, r.n_heads, r.head_dim,
                           r.head_dim), jnp.float32),
            )
        if cfg.family == "hybrid":
            m = self.mcfg

            def mcache(lead):
                return (
                    jnp.zeros(lead + (batch, m.d_conv - 1, m.conv_channels),
                              cfg.dtype),
                    jnp.zeros(lead + (batch, m.n_heads, m.d_state, m.head_dim),
                              jnp.float32),
                )

            return {
                "mamba": mcache((self.n_groups, cfg.attn_every)),
                "attn": attn_cache(self.n_groups),
                "tail": mcache((self.n_tail,)) if self.n_tail else None,
            }
        return attn_cache(cfg.n_layers)

    def forward_cached(self, params, tokens, caches, positions=None,
                       vision_embeds=None, vision_mask=None):
        """Prefill (S>1) or decode (S=1) against caches; returns
        (logits_last (B,V), new_caches)."""
        B, S = tokens.shape
        if positions is None:
            base = self._cache_pos(caches)
            positions = base + jnp.broadcast_to(jnp.arange(S), (B, S))
        x = self._embed(params, tokens, vision_embeds, vision_mask)
        h, new_caches, _ = self._backbone(params, x, positions, caches=caches)
        logits = self._logits(params, h[:, -1:])
        return logits[:, 0], new_caches

    def _cache_pos(self, caches):
        cfg = self.cfg
        if cfg.family == "ssm":
            return 0  # rwkv is position-free
        if cfg.family == "hybrid":
            return caches["attn"]["pos"][0]
        return caches["pos"][0]
