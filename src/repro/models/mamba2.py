"""Mamba2 (SSD) block in pure JAX — the zamba2 backbone.

Training path uses the chunked SSD formulation (intra-chunk attention-like
matmuls + inter-chunk state scan) so compute lands on the MXU; the Pallas
kernel in ``repro.kernels.mamba2_ssd`` implements the same tiling for TPU and
is validated against the naive recurrence in its ref.py.

Decode keeps an O(1) recurrent state per layer: (conv tail, SSM state
(heads, headdim, state)) — this is what makes the 500k-context cell feasible.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import rms_norm


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_channels(self) -> int:
        # conv runs over x and the (single-group) B, C projections
        return self.d_inner + 2 * self.d_state

    @property
    def in_dim(self) -> int:
        # [z, x, B, C, dt]
        return 2 * self.d_inner + 2 * self.d_state + self.n_heads


def causal_conv(x, w, state=None):
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C).
    With ``state`` (B, K-1, C) uses it as left context (decode);
    returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(y), xp[:, -(K - 1) :]


def _split_proj(cfg: Mamba2Config, zxbcdt):
    di, ds, nh = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + cfg.conv_channels]
    dt = zxbcdt[..., -nh:]
    return z, xBC, dt


def ssd_chunked(xh, dt, A_log, B, C, D, chunk: int = 128, h0=None):
    """Chunked SSD scan.

    xh: (Bt, S, H, P) inputs per head; dt: (Bt, S, H) softplus'd step sizes;
    A_log: (H,) (A = -exp(A_log)); B, C: (Bt, S, N); D: (H,) skip.
    Returns (y (Bt,S,H,P), final_state (Bt,H,N,P)).
    """
    Bt, S, H, Pd = xh.shape
    N = B.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    a = -jnp.exp(A_log.astype(jnp.float32))  # (H,)
    dt = dt.astype(jnp.float32)
    la = dt * a  # (Bt,S,H) log decay per step
    xdt = xh.astype(jnp.float32) * dt[..., None]  # Δ-scaled input

    # chunk-major layout for scan: (nc, Bt, Lc, ...)
    rc = lambda t: t.reshape((Bt, nc, chunk) + t.shape[2:]).swapaxes(0, 1)
    la_c, x_c = rc(la), rc(xdt)
    B_c, C_c = rc(B.astype(jnp.float32)), rc(C.astype(jnp.float32))

    if h0 is None:
        h0 = jnp.zeros((Bt, H, N, Pd), jnp.float32)
    idx = jnp.arange(chunk)
    tri = idx[:, None] >= idx[None, :]

    def chunk_body(h, inp):
        la_k, x_k, B_k, C_k = inp  # (Bt,Lc,H), (Bt,Lc,H,P), (Bt,Lc,N) ×2
        cum = jnp.cumsum(la_k, axis=1)  # (Bt,Lc,H)
        total = cum[:, -1]  # (Bt,H)
        # intra-chunk: M_ij = (C_i·B_j) exp(cum_i - cum_j), j ≤ i.  Mask the
        # exponent BEFORE exp — the upper triangle overflows to inf.
        GB = jnp.einsum("bis,bjs->bij", C_k, B_k)  # (Bt,Lc,Lc)
        ldec = cum[:, :, None, :] - cum[:, None, :, :]  # (Bt,i,j,H)
        M = GB[..., None] * jnp.exp(
            jnp.where(tri[None, :, :, None], ldec, -1e30)
        )
        y = jnp.einsum("bijh,bjhp->bihp", M, x_k)
        # inter-chunk: y_i += (C_i · h) * exp(cum_i)
        y += jnp.einsum("bis,bhsp->bihp", C_k, h) * jnp.exp(cum)[..., None]
        # state update: h' = exp(total) h + Σ_j exp(total - cum_j) B_j ⊗ x_j
        wx = jnp.exp(total[:, None] - cum)[..., None] * x_k  # (Bt,Lc,H,P)
        h = h * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bjs,bjhp->bhsp", B_k, wx
        )
        return h, y

    h_final, ys = jax.lax.scan(chunk_body, h0, (la_c, x_c, B_c, C_c))
    y = ys.swapaxes(0, 1).reshape(Bt, S, H, Pd)
    y = y + xh.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(xh.dtype), h_final


def ssd_decode_step(x1, dt1, A_log, B1, C1, D, h):
    """One-token recurrence. x1: (Bt,H,P); dt1: (Bt,H); B1,C1: (Bt,N);
    h: (Bt,H,N,P) → (y (Bt,H,P), h')."""
    a = -jnp.exp(A_log.astype(jnp.float32))
    dt1 = dt1.astype(jnp.float32)
    decay = jnp.exp(dt1 * a)  # (Bt,H)
    upd = jnp.einsum("bs,bhp->bhsp", B1.astype(jnp.float32),
                     x1.astype(jnp.float32) * dt1[..., None])
    h = h * decay[..., None, None] + upd
    y = jnp.einsum("bs,bhsp->bhp", C1.astype(jnp.float32), h)
    y = y + x1.astype(jnp.float32) * D[None, :, None]
    return y.astype(x1.dtype), h


def mamba2_forward(p, x, cfg: Mamba2Config, *, cache=None, chunk: int = 128):
    """Full block.  x: (Bt, S, D).  p holds in_proj (D, in_dim), conv_w
    (K, conv_channels), A_log (H,), D (H,), dt_bias (H,), norm_w (d_inner,),
    out_proj (d_inner, D).  cache = (conv_state, ssm_state) for decode."""
    Bt, S, _ = x.shape
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    conv_state = cache[0] if cache is not None else None
    xBC, new_conv = causal_conv(xBC, p["conv_w"], conv_state)
    xh = xBC[..., : cfg.d_inner].reshape(Bt, S, cfg.n_heads, cfg.head_dim)
    Bm = xBC[..., cfg.d_inner : cfg.d_inner + cfg.d_state]
    Cm = xBC[..., cfg.d_inner + cfg.d_state :]

    chunk = min(chunk, S)
    if cache is not None and S == 1:
        y1, new_h = ssd_decode_step(
            xh[:, 0], dt[:, 0], p["A_log"], Bm[:, 0], Cm[:, 0], p["D"], cache[1]
        )
        y = y1[:, None]
    else:
        h0 = cache[1] if cache is not None else None
        y, new_h = ssd_chunked(xh, dt, p["A_log"], Bm, Cm, p["D"], chunk, h0)

    y = y.reshape(Bt, S, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out = y @ p["out_proj"]
    return out, (new_conv, new_h)


def mamba2_ref_scan(xh, dt, A_log, B, C, D):
    """Naive per-step recurrence — oracle for the chunked path and the
    Pallas kernel."""
    Bt, S, H, Pd = xh.shape
    N = B.shape[-1]
    h = jnp.zeros((Bt, H, N, Pd), jnp.float32)

    def body(h, t):
        y, h = ssd_decode_step(xh[:, t], dt[:, t], A_log, B[:, t], C[:, t], D, h)
        return h, y

    _, ys = jax.lax.scan(body, h, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3)


def init_mamba2_params(pf, path: str, cfg: Mamba2Config, n_layers: int, fsdp_axes):
    """Stacked (n_layers, ...) parameter block + specs."""
    from jax.sharding import PartitionSpec as P

    L = (n_layers,)
    pf.param(f"{path}/ln", L + (cfg.d_model,), P(None, None), init="zeros")
    pf.param(f"{path}/in_proj", L + (cfg.d_model, cfg.in_dim),
             P(None, fsdp_axes, "model"))
    pf.param(f"{path}/conv_w", L + (cfg.d_conv, cfg.conv_channels),
             P(None, None, "model"))
    pf.param(f"{path}/A_log", L + (cfg.n_heads,), P(None, "model"), init="zeros")
    pf.param(f"{path}/D", L + (cfg.n_heads,), P(None, "model"), init="ones")
    pf.param(f"{path}/dt_bias", L + (cfg.n_heads,), P(None, "model"), init="zeros")
    pf.param(f"{path}/norm_w", L + (cfg.d_inner,), P(None, "model"), init="zeros")
    pf.param(f"{path}/out_proj", L + (cfg.d_inner, cfg.d_model),
             P(None, "model", fsdp_axes))
