"""Model factory: config → model instance with a uniform interface.

Every model exposes:
  init(key) -> (params, specs)         specs: PartitionSpec tree
  loss_fn(params, batch) -> scalar
  init_cache(batch, max_len) -> caches
  forward_cached(params, tokens, caches, ...) -> (logits, caches)   [decode]
Whisper additionally has encode/prefill (enc-dec).
"""
from __future__ import annotations

from .transformer import DecoderLM, ModelConfig
from .whisper import WhisperModel


def build_model(cfg: ModelConfig, mesh=None):
    if cfg.family == "audio":
        return WhisperModel(cfg, mesh=mesh)
    return DecoderLM(cfg, mesh=mesh)
