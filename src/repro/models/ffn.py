"""Feed-forward blocks: gated MLP (SwiGLU/GeGLU) and Mixture-of-Experts.

MoE uses TPU-native capacity-based dispatch (GShard/Switch lineage, the
hardware adaptation of GPU "dropless" grouped GEMMs — DESIGN.md §2): tokens
are sorted by expert, placed into an (E, capacity) slot grid, and processed
with batched einsums whose backward passes are einsums of the same shape.
``jax.lax.ragged_dot`` was rejected after measurement: its autodiff
densifies over ALL experts (observed 48× FLOPs and TB-scale temps on the
384-expert config).

Expert hidden dims are sharded over the ``model`` axis and expert weights
FSDP-sharded over data axes; dispatch runs inside shard_map (token-local,
no all-to-all).  Overflowing tokens are dropped (standard; the Switch aux
loss keeps routing balanced) — tests use capacity_factor ≥ E/top_k so drops
cannot occur when validating math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gated_mlp(x, w_gate, w_up, w_down, act: str = "silu"):
    g = x @ w_gate
    u = x @ w_up
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    return (a * u) @ w_down


def moe_block(
    x,
    router_w,  # (D, E)
    w_gate,  # (E, D, F)
    w_up,  # (E, D, F)
    w_down,  # (E, F, D)
    *,
    top_k: int,
    act: str = "silu",
    capacity_factor: float = 1.25,
):
    """x: (T, D) flat tokens → (out (T, D), aux load-balance loss)."""
    T, D = x.shape
    E = w_gate.shape[0]
    cap = int(max(top_k, capacity_factor * T * top_k / E))
    cap = min(cap, T * top_k)

    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (T, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # sort (token, k) assignments by expert; position within group = slot
    flat_expert = expert_idx.reshape(-1)  # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T), top_k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    s_expert = flat_expert[order]
    s_token = flat_token[order]
    s_gate = flat_gate[order]

    group_sizes = jnp.bincount(s_expert, length=E)
    starts = jnp.cumsum(group_sizes) - group_sizes  # (E,)
    pos_in_group = jnp.arange(T * top_k) - starts[s_expert]
    keep = pos_in_group < cap
    slot = jnp.where(keep, s_expert * cap + pos_in_group, E * cap)  # drop → pad

    # dispatch: (E*cap+1, D) slot grid (last row = dropped-token sink)
    xs = jnp.zeros((E * cap + 1, D), x.dtype).at[slot].set(x[s_token])
    xe = xs[: E * cap].reshape(E, cap, D)

    g = jnp.einsum("ecd,edf->ecf", xe, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xe, w_up)
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    ye = jnp.einsum("ecf,efd->ecd", a * u, w_down)  # (E, cap, D)

    # combine: gather each kept assignment's row, weight, scatter-add by token
    ys = jnp.concatenate(
        [ye.reshape(E * cap, D), jnp.zeros((1, D), ye.dtype)], axis=0
    )
    contrib = ys[slot] * s_gate[:, None].astype(ye.dtype)  # (T*K, D)
    out = jnp.zeros((T, D), ye.dtype).at[s_token].add(contrib)

    # Switch-style auxiliary load-balance loss
    me = probs.mean(axis=0)
    ce = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32).mean(axis=0)
    aux = E * jnp.sum(me * ce)
    return out, aux
