from .pipeline import SyntheticLM, make_batch_iter  # noqa: F401
