"""Deterministic synthetic data pipeline.

Tokens are a pure function of (stream seed, step, position) — a counter-mode
hash — so any host can materialize exactly its shard without coordination,
restarts are bit-exact from the step counter alone (no data-state in the
checkpoint beyond ``step``), and elastic re-sharding is trivial: host h of H
serves rows where ``row % H == h``.

The target distribution is a learnable mixture (Zipf unigram + short-range
copy structure) so a real training signal exists: loss decreases measurably
within a few hundred steps on the quickstart config.

Prefetch: a double-buffered iterator overlaps host batch synthesis with
device compute (jax dispatch is async; we just stay one batch ahead).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


def _hash64(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> 30)) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> 27)) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> 31)


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    copy_period: int = 64  # tokens repeat with this period → learnable

    def batch(self, step: int, host: int = 0, n_hosts: int = 1) -> dict:
        """Host-sharded batch: rows ``host::n_hosts`` of the global batch."""
        rows = np.arange(self.global_batch, dtype=np.uint64)[host::n_hosts]
        pos = np.arange(self.seq_len + 1, dtype=np.uint64)
        key = (
            np.uint64(self.seed) * np.uint64(1_000_003)
            + np.uint64(step) * np.uint64(7_919)
        )
        base = _hash64(key + rows[:, None] * np.uint64(2_654_435_761))
        # periodic copy structure: position p reuses the hash of p mod period
        eff = pos % np.uint64(self.copy_period)
        h = _hash64(base + eff[None, :] * np.uint64(0x9E3779B97F4A7C15))
        # Zipf-ish unigram: square the uniform to skew toward low ids
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        toks = (u * u * self.vocab).astype(np.int32)
        return {
            "tokens": toks[:, : self.seq_len],
            "labels": toks[:, 1:],
        }


def make_batch_iter(ds: SyntheticLM, start_step: int = 0, host: int = 0,
                    n_hosts: int = 1, prefetch: int = 2) -> Iterator[dict]:
    """Double-buffered iterator (synthesis overlaps device compute)."""
    import collections

    buf = collections.deque()
    step = start_step
    for _ in range(prefetch):
        buf.append(ds.batch(step, host, n_hosts))
        step += 1
    while True:
        yield buf.popleft()
        buf.append(ds.batch(step, host, n_hosts))
        step += 1
