"""Checkpointing with elastic re-sharding.

Layout: ``<dir>/step_<N>/<flat-key>.npy`` + manifest.json.  Leaves are saved
as host numpy (mesh-independent), so a checkpoint written on one mesh
restores onto ANY mesh/new process count — restore device_puts each leaf
with the target sharding (elastic scaling / failure recovery path).

Writes are atomic (tmp dir + rename) and optionally asynchronous (a
background thread snapshots host copies first — the train loop never blocks
on disk).  ``keep`` bounds retained checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "##"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: Any, *, async_write: bool = False,
         keep: int = 3):
    """Snapshot → (optionally background) atomic write."""
    host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    treedef = jax.tree.structure(tree)

    def write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        for k, v in host.items():
            np.save(os.path.join(tmp, f"{k}.npy"), v)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(host),
                       "treedef": str(treedef)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # retention
        steps = sorted(latest_steps(ckpt_dir))
        for s in steps[:-keep]:
            shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                          ignore_errors=True)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_"):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = latest_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; if ``shardings`` is given each
    leaf is placed with it (elastic re-shard onto the current mesh)."""
    base = os.path.join(ckpt_dir, f"step_{step}")
    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for k, leaf in flat_like.items():
        arr = np.load(os.path.join(base, f"{k}.npy"))
        want = getattr(leaf, "dtype", None)
        if want is not None and arr.dtype != want:
            arr = arr.astype(want)
        if k in flat_sh:
            loaded[k] = jax.device_put(arr, flat_sh[k])
        else:
            loaded[k] = jax.device_put(arr)
    leaves = [loaded[k] for k in _flatten(like)]
    return jax.tree.unflatten(jax.tree.structure(like), leaves)
