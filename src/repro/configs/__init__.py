from .registry import (  # noqa: F401
    ARCHS,
    SHAPES,
    all_cells,
    get_arch,
    get_shape,
    shape_applicable,
)
