"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]
q-heads pad 40→48, kv 10→12 for TP=16 (DESIGN.md §5)."""
from repro.models.transformer import ModelConfig


def config(**overrides) -> ModelConfig:
    base = dict(
        name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=10, d_ff=17920, vocab=100352,
        rope_theta=1e4, tp=16, fsdp=True, remat="full",
    )
    base.update(overrides)
    return ModelConfig(**base)
