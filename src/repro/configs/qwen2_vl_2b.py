"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE (t/h/w sections 16/24/24 of the 64-wide half-dim),
dynamic-resolution vision frontend is a STUB (input_specs supplies patch
embeddings + a (3,B,S) position grid).  [arXiv:2409.12191; hf]"""
from repro.models.transformer import ModelConfig


def config(**overrides) -> ModelConfig:
    base = dict(
        name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
        n_heads=12, n_kv_heads=2, d_ff=8960, vocab=151936, head_dim=128,
        mrope_sections=(16, 24, 24), rope_theta=1e6, tie_embeddings=True,
        qkv_bias=True, tp=16, fsdp=False, remat="full",
    )
    base.update(overrides)
    return ModelConfig(**base)
