"""zamba2-7b [hybrid]: 81 blocks d_model=3584 32H (kv=32) d_ff=14336
vocab=32000 ssm_state=64 — Mamba2 backbone + shared (weight-tied) attention
block every 6th position: 13 groups × (5 mamba + 1 shared attn) + 3 tail
mamba = 81 blocks.  [arXiv:2411.15242; unverified]"""
from repro.models.transformer import ModelConfig


def config(**overrides) -> ModelConfig:
    base = dict(
        name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
        n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
        ssm_state=64, attn_every=5, rope_theta=1e4,
        tp=16, fsdp=True, remat="full",
    )
    base.update(overrides)
    return ModelConfig(**base)
