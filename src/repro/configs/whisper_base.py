"""whisper-base [audio]: 6+6L enc-dec d_model=512 8H d_ff=2048 vocab=51865 —
conv/mel frontend is a STUB (input_specs supplies 1500 precomputed frame
embeddings).  [arXiv:2212.04356; unverified]"""
from repro.models.transformer import ModelConfig


def config(**overrides) -> ModelConfig:
    base = dict(
        name="whisper-base", family="audio", n_layers=6, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865,
        tp=16, fsdp=False, remat="full",
    )
    base.update(overrides)
    return ModelConfig(**base)
