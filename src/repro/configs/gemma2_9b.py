"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local(4096)+global alternating, logit softcaps, post-norms,
GeGLU.  [arXiv:2408.00118; hf]"""
from repro.models.transformer import ModelConfig


def config(**overrides) -> ModelConfig:
    base = dict(
        name="gemma2-9b", family="dense", n_layers=42, d_model=3584,
        n_heads=16, n_kv_heads=8, d_ff=14336, vocab=256000,
        head_dim=256, act="gelu", window=4096, alt_window=True,
        attn_softcap=50.0, final_softcap=30.0, post_norms=True,
        tie_embeddings=True, rope_theta=1e4, tp=16, fsdp=True, remat="full",
    )
    base.update(overrides)
    return ModelConfig(**base)
