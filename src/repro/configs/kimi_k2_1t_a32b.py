"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, 384 experts top-8 — trillion-param MoE (paper-table config).
Expert hidden dims TP-sharded over `model`, expert weights FSDP-sharded over
(pod, data) — mandatory to fit 16 GB/chip.  [arXiv:2501.kimi2; unverified]"""
from repro.models.transformer import ModelConfig


def config(**overrides) -> ModelConfig:
    base = dict(
        name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
        n_heads=64, n_kv_heads=8, d_ff=2048, vocab=163840,
        n_experts=384, top_k=8, rope_theta=5e4,
        tp=16, fsdp=True, remat="full",
    )
    base.update(overrides)
    return ModelConfig(**base)
