"""rwkv6-7b [ssm]: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536 —
Finch: WKV6 with data-dependent decay; O(1) decode state.
[arXiv:2404.05892; hf]"""
from repro.models.transformer import ModelConfig


def config(**overrides) -> ModelConfig:
    base = dict(
        name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096,
        n_heads=64, n_kv_heads=64, d_ff=14336, vocab=65536,
        tp=16, fsdp=True, remat="full",
    )
    base.update(overrides)
    return ModelConfig(**base)
