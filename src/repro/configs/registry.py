"""Architecture + input-shape registry (the assigned 10 × 4 grid).

Shapes (per the assignment):
  train_4k     seq 4,096   global_batch 256  → train_step
  prefill_32k  seq 32,768  global_batch 32   → serve_prefill
  decode_32k   seq 32,768  global_batch 128  → serve_step (1 new token)
  long_500k    seq 524,288 global_batch 1    → serve_step; SSM/hybrid only
                (full-attention archs are skipped — DESIGN.md §4; gemma2's
                alternating stack still contains full global-attn layers,
                so it is skipped too)
Encoder-decoder (whisper) has a decoder, so decode shapes run.
"""
from __future__ import annotations

import dataclasses
import importlib


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

ARCHS = (
    "qwen3-1.7b",
    "phi3-medium-14b",
    "gemma2-9b",
    "qwen1.5-0.5b",
    "zamba2-7b",
    "rwkv6-7b",
    "kimi-k2-1t-a32b",
    "phi3.5-moe-42b-a6.6b",
    "qwen2-vl-2b",
    "whisper-base",
)

# Sub-quadratic decode state: the only archs that run long_500k.
LONG_CONTEXT_OK = {"zamba2-7b", "rwkv6-7b"}


def get_arch(arch_id: str, **overrides):
    """Load ``src/repro/configs/<arch>.py`` and build its ModelConfig."""
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}"
    )
    return mod.config(**overrides)


def get_shape(name: str) -> Shape:
    return SHAPES[name]


def shape_applicable(arch_id: str, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if shape_name == "long_500k" and arch_id not in LONG_CONTEXT_OK:
        return False, "full-attention arch: 500k decode needs sub-quadratic state"
    return True, ""


def all_cells():
    """The 40 assigned (arch × shape) cells with skip annotations."""
    for arch in ARCHS:
        for shape in SHAPES:
            ok, why = shape_applicable(arch, shape)
            yield arch, shape, ok, why
