"""Conveyor-DP: the paper's belt as a gradient/parameter sync mode.

Mapping (DESIGN.md §2): each *pod* (or DP group) is a belt server whose
"database" is its parameter replica.  A training step's parameter delta is a
**commutative state update** (additive), so the belt degenerates to its
cheapest form: updates never conflict, the token ring only carries deltas,
and every replica converges to the identical parameter state once deltas
drain — serializability for free, with 1..R−1 steps of staleness instead of
a blocking all-reduce on the critical path.

Two faces:

* ``ConveyorDP`` — host-driven belt across R replicas (cross-pod DCN is
  host-mediated in practice).  Works on any jitted per-replica step; int8 +
  error-feedback compression on the wire (optim.compress).
* ``ring_delta_exchange`` — the in-JAX hop (ppermute over the ``pod`` axis)
  used by the dry-run/roofline to compare collective bytes against psum.

Sync baseline ≙ MySQL-Cluster-style blocking coordination; Conveyor-DP ≙
Eliá.  benchmarks/conveyor_dp.py measures both; tests assert replica
convergence and loss parity.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import int8_compress, int8_decompress


@dataclasses.dataclass
class BeltStats:
    bytes_shipped: int = 0
    bytes_uncompressed: int = 0
    rounds: int = 0


class ConveyorDP:
    """Host-level belt over R parameter replicas."""

    def __init__(self, step_fn: Callable, params_list, opt_list,
                 compress: bool = True):
        self.step_fn = step_fn
        self.R = len(params_list)
        self.params = list(params_list)
        self.opt = list(opt_list)
        self.compress = compress
        self.errors = [None] * self.R
        # token: list of (origin, packed-deltas); an entry is appended when
        # its origin HOLDS the token (Algorithm 2 line 19) and removed when
        # the origin receives it back a full rotation later (line 13) — in
        # between every other replica applies it exactly once.
        self.token: list = []
        # non-holders buffer (merge) their deltas locally until their turn —
        # the belt's queue Q.
        self.pending: list = [None] * self.R
        self.token_pos = 0
        self.stats = BeltStats()

    def _ship(self, deltas, r):
        if not self.compress:
            self.stats.bytes_shipped += sum(
                x.size * x.dtype.itemsize for x in jax.tree.leaves(deltas)
            )
            return ("raw", deltas)
        q, scales, self.errors[r] = int8_compress(deltas, self.errors[r])
        self.stats.bytes_shipped += sum(
            x.size for x in jax.tree.leaves(q)
        ) + 4 * len(jax.tree.leaves(scales))
        return ("int8", (q, scales))

    def _unship(self, packed):
        kind, payload = packed
        if kind == "raw":
            return payload
        return int8_decompress(*payload)

    def _buffer(self, r, delta):
        if self.pending[r] is None:
            self.pending[r] = delta
        else:
            self.pending[r] = jax.tree.map(
                lambda a, b: a + b, self.pending[r], delta
            )

    def _token_turn(self):
        """RECEIVETOKEN at the current holder: apply foreign entries, drop
        own returning entries, append the (merged) pending delta."""
        holder = self.token_pos % self.R
        kept = []
        for origin, packed in self.token:
            if origin == holder:
                continue  # full circulation: everyone has applied it
            d = self._unship(packed)
            self.params[holder] = jax.tree.map(
                lambda p, dd: (p.astype(jnp.float32) + dd).astype(p.dtype),
                self.params[holder], d,
            )
            kept.append((origin, packed))
        self.token = kept
        if self.pending[holder] is not None:
            self.token.append(
                (holder, self._ship(self.pending[holder], holder))
            )
            self.pending[holder] = None
        self.token_pos += 1

    def round(self, batches) -> list[dict]:
        """One belt round: every replica steps locally (local op, no
        coordination — the paper's point); the holder takes its token turn."""
        R = self.R
        metrics = []
        for r in range(R):
            old = self.params[r]
            self.params[r], self.opt[r], m = self.step_fn(
                self.params[r], self.opt[r], batches[r]
            )
            delta = jax.tree.map(
                lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)),
                self.params[r], old,
            )
            self._buffer(r, delta)
            metrics.append({k: float(np.asarray(v)) for k, v in m.items()})
            self.stats.bytes_uncompressed += sum(
                x.size * 4 for x in jax.tree.leaves(delta)
            )
        self._token_turn()
        self.stats.rounds += 1
        return metrics

    def drain(self):
        """2R extra token turns with no new work: every pending delta is
        published and completes a full rotation → replicas identical (up to
        int8 residuals when compressing)."""
        for _ in range(2 * self.R):
            self._token_turn()

    def replica_params(self, r: int):
        return self.params[r]


def ring_delta_exchange(deltas, ring_axis: str, n: int):
    """In-JAX belt hop for the dry-run: int8-quantize a delta pytree, one
    ppermute around ``ring_axis``, dequantize and apply.  Collective bytes =
    ¼ of a bf16 all-gather of the same tree (per hop)."""

    def one(x):
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        perm = [(i, (i + 1) % n) for i in range(n)]
        q = jax.lax.ppermute(q, ring_axis, perm)
        s = jax.lax.ppermute(scale[None], ring_axis, perm)[0]
        return q.astype(jnp.float32) * s

    return jax.tree.map(one, deltas)
