"""Roofline term extraction (EXPERIMENTS.md §Roofline).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  ``compiled.cost_analysis()`` is PER-DEVICE under SPMD (verified
empirically), so terms divide by per-chip peaks directly.

collective_bytes parses the compiled HLO text: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute contributes its
output operand bytes (per-device shapes in post-SPMD HLO).

cost_analysis does NOT multiply while-loop (lax.scan) bodies by their trip
count, so scanned-layer graphs undercount — the roofline harness therefore
compiles shallow UNROLLED variants and extrapolates per-layer deltas
(benchmarks/roofline.py); these helpers stay pure.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")

# e.g.  "bf16[8,128,2048]{2,1,0} all-gather(...)" — possibly a tuple result
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (output shapes)."""
    out = {k: 0 for k in _COLL}
    for line in hlo_text.splitlines():
        s = line.strip()
        for kind in _COLL:
            # match "<shape> <name> = <shape> kind(" or fused forms
            if f" {kind}(" in s or s.startswith(f"{kind}("):
                # result shape is everything before " <op-name> =" — simpler:
                # take the shape annotation right before the op kind token
                idx = s.find(f"{kind}(")
                lhs = s[:idx]
                # rightmost shape group in lhs is the result type
                shapes = _SHAPE_RE.findall(lhs)
                if shapes:
                    # rebuild the tuple of result shapes: use all groups in
                    # the segment after '=' if present
                    eq = lhs.find("=")
                    seg = lhs[eq + 1:] if eq >= 0 else lhs
                    out[kind] += _shape_bytes(seg)
                break
    return out


def roofline_terms(per_device: dict, coll: dict) -> dict:
    """The three terms, in seconds (per device = per chip)."""
    t_compute = per_device["flops"] / PEAK_FLOPS
    t_memory = per_device.get("bytes_accessed", 0.0) / HBM_BW
    t_coll = sum(coll.values()) / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
    }
