"""Cell builder: (arch × shape × mesh) → jittable step + abstract inputs +
shardings.  Used by the dry-run, the roofline harness, and the real train /
serve drivers.

``build_cell`` returns everything needed to
``jax.jit(fn, in_shardings=...).lower(*args).compile()`` without allocating
any real array (ShapeDtypeStructs all the way down).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, get_shape
from repro.models import build_model
from repro.models.transformer import ModelConfig
from repro.optim import AdamWConfig, adamw_update, adamw_state_specs, cosine_warmup
from repro.optim.adamw import adamw_init
from .mesh import data_axes


# -- spec plumbing -----------------------------------------------------------


def normalize_spec(spec: P, mesh) -> P:
    """Drop mesh axes a spec references that this mesh doesn't have (e.g.
    "pod" on the single-pod mesh)."""
    names = set(mesh.axis_names)

    def fix(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(fix(e) for e in spec))


def shardings(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, normalize_spec(s, mesh)),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# -- batch / cache specs -------------------------------------------------------


def batch_specs(cfg: ModelConfig, seq: int, batch: int, mesh):
    """(ShapeDtypeStructs, PartitionSpecs) for one training batch."""
    da = data_axes(mesh)
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    sds = {"tokens": tok, "labels": tok}
    specs = {"tokens": P(da, None), "labels": P(da, None)}
    if cfg.family == "vlm":
        n_img = min(seq // 4, 4096)
        sds["positions"] = jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)
        sds["vision_embeds"] = jax.ShapeDtypeStruct(
            (batch, n_img, cfg.d_model), cfg.dtype
        )
        sds["vision_mask"] = jax.ShapeDtypeStruct((batch, seq), jnp.bool_)
        specs["positions"] = P(None, da, None)
        specs["vision_embeds"] = P(da, None, None)
        specs["vision_mask"] = P(da, None)
    if cfg.family == "audio":
        sds["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.dtype)
        specs["frames"] = P(da, None, None)
    return sds, specs


def cache_specs(model, cfg: ModelConfig, batch: int, max_len: int, mesh):
    """(abstract caches, spec tree).  long-context (batch == 1) shards the
    sequence / state dims over the data axes instead of batch —
    sequence-parallel flash-decode, combined by GSPMD's partial softmax."""
    da = data_axes(mesh)
    long_ctx = batch == 1
    kv_tp = "model" if cfg.kv_sharded else None
    caches = jax.eval_shape(lambda: model.init_cache(batch, max_len))

    # when KV heads don't divide TP, shard the cache SEQ dim over `model`
    # instead (sequence-parallel flash-decode; GSPMD combines the partial
    # softmax) — otherwise the replicated cache dominates HBM (observed
    # 84 GiB/dev on gemma2 decode_32k).
    seq_tp = None if cfg.kv_sharded else "model"

    def attn_spec(ndim_lead):
        lead = (None,) * ndim_lead
        if long_ctx:
            return {
                "k": P(*lead, None, da, kv_tp, None),
                "v": P(*lead, None, da, kv_tp, None),
                "pos": P(*((None,) * ndim_lead)) if ndim_lead else P(),
            }
        return {
            "k": P(*lead, da, seq_tp, kv_tp, None),
            "v": P(*lead, da, seq_tp, kv_tp, None),
            "pos": P(*((None,) * ndim_lead)) if ndim_lead else P(),
        }

    if cfg.family == "ssm":
        bspec = None if long_ctx else da
        return caches, (
            P(None, bspec, None),
            P(None, bspec, None),
            P(None, bspec, "model", None, None),
        )
    if cfg.family == "hybrid":
        bspec = None if long_ctx else da

        def mspec(n_lead):
            lead = (None,) * n_lead
            return (
                P(*lead, bspec, None, "model"),
                P(*lead, bspec, "model", None, None),
            )

        return caches, {
            "mamba": mspec(2),
            "attn": attn_spec(1),
            "tail": mspec(1) if model.n_tail else None,
        }
    if cfg.family == "audio":
        h_tp = "model"
        b = da if not long_ctx else None
        return caches, {
            "self": {
                "k": P(None, b, None if not long_ctx else da, h_tp, None),
                "v": P(None, b, None if not long_ctx else da, h_tp, None),
                "pos": P(None),
            },
            "cross": {
                "k": P(None, b, None, h_tp, None),
                "v": P(None, b, None, h_tp, None),
            },
        }
    return caches, attn_spec(1)


# -- step functions ------------------------------------------------------------


def make_train_step(model, opt_cfg: AdamWConfig, total_steps: int = 10_000,
                    microbatches: int = 1, unroll_micro: bool = False,
                    grad_shardings=None):
    """Standard synchronous step.  ``microbatches > 1`` enables gradient
    accumulation: per-micro backward completes before the next micro starts,
    so live rematerialization residuals shrink by the micro factor.
    ``unroll_micro`` unrolls the accumulation loop (roofline mode —
    cost_analysis counts a lax.scan body once)."""

    def grad_once(params, batch):
        return jax.value_and_grad(model.loss_fn)(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grad_once(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((microbatches, a.shape[0] // microbatches)
                                    + a.shape[1:]),
                batch,
            )

            def acc(carry, mb):
                loss_sum, g = carry
                li, gi = grad_once(params, mb)
                g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g, gi
                )
                return (loss_sum + li, g), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            carry = (jnp.zeros((), jnp.float32), g0)
            if unroll_micro:
                for i in range(microbatches):
                    mb = jax.tree.map(lambda a: a[i], micro)
                    carry, _ = acc(carry, mb)
                loss_sum, grads = carry
            else:
                (loss_sum, grads), _ = jax.lax.scan(acc, carry, micro)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        if grad_shardings is not None:
            # pin grads to the parameter layout: the DP reduction lowers to
            # reduce-scatter instead of all-reduce (§Perf I-A4)
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        lr_scale = cosine_warmup(opt_state["step"], total=total_steps)
        new_params, new_opt, gnorm = adamw_update(
            opt_cfg, params, grads, opt_state, lr_scale
        )
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr_scale": jnp.asarray(lr_scale, jnp.float32)}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model, cfg: ModelConfig, seq: int, batch: int,
                      cache_sharding=None):
    def constrain(caches):
        if cache_sharding is None:
            return caches
        return jax.lax.with_sharding_constraint(caches, cache_sharding)

    if cfg.family == "audio":
        def prefill(params, frames, tokens):
            logits, caches = model.prefill(params, frames, tokens)
            return logits, constrain(caches)

        return prefill

    def prefill(params, tokens):
        caches = model.init_cache(tokens.shape[0], seq)
        logits, caches = model.forward_cached(
            params, tokens, caches,
            positions=(jnp.broadcast_to(
                jnp.arange(seq), (3, batch, seq)) if cfg.family == "vlm" else None),
        )
        return logits, constrain(caches)

    return prefill


def make_decode_step(model, cfg: ModelConfig):
    if cfg.family == "audio":
        def decode(params, caches, tokens):
            return model.forward_cached(params, tokens, caches)

        return decode

    def decode(params, caches, tokens):
        if cfg.family == "vlm":
            B = tokens.shape[0]
            pos = model._cache_pos(caches)
            positions = jnp.broadcast_to(pos, (3, B, 1))
            return model.forward_cached(params, tokens, caches,
                                        positions=positions)
        return model.forward_cached(params, tokens, caches)

    return decode


# -- cell assembly ---------------------------------------------------------------


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    fn: Any  # the step callable
    args: tuple  # abstract (SDS) arguments
    in_shardings: tuple
    donate: tuple  # argnums to donate
    model: Any


def build_cell(arch_id: str, shape_name: str, mesh, *, layer_mode="scan",
               microbatches: int = 1, opt_cfg: AdamWConfig | None = None,
               overrides: dict | None = None) -> Cell:
    shape = get_shape(shape_name)
    ov = dict(overrides or {})
    if shape.kind == "decode":
        # Serving deployments keep params TP-sharded but NOT FSDP-sharded:
        # re-gathering FSDP shards over ICI on every decoded token costs
        # ~74 ms/token on phi3-medium (§Perf I-C2) for zero memory benefit
        # at decode batch sizes.
        ov.setdefault("fsdp", False)
    cfg = get_arch(arch_id, layer_mode=layer_mode, **ov)
    model = build_model(cfg, mesh)
    params_sds, specs = model.init(jax.random.PRNGKey(0), abstract=True)
    p_shard = shardings(specs, mesh)
    da = data_axes(mesh)

    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        bs, bspec = batch_specs(cfg, S, B, mesh)
        ocfg = opt_cfg or AdamWConfig()
        opt_sds = jax.eval_shape(
            lambda p: adamw_init(p, ocfg.moment_dtype), params_sds
        )
        opt_shard = shardings(adamw_state_specs(specs), mesh)
        fn = make_train_step(model, ocfg, microbatches=microbatches,
                             unroll_micro=layer_mode == "unroll",
                             grad_shardings=p_shard)
        return Cell(arch_id, shape_name, cfg, fn,
                    (params_sds, opt_sds, bs),
                    (p_shard, opt_shard, shardings(bspec, mesh)),
                    donate=(0, 1), model=model)

    if shape.kind == "prefill":
        if cfg.family == "audio":
            model.encoder_seq = S
            _, cspec = cache_specs(model, cfg, B, S, mesh)
            csh = shardings(cspec, mesh)
            frames = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype)
            toks = jax.ShapeDtypeStruct((B, 16), jnp.int32)
            fn = make_prefill_step(model, cfg, S, B, cache_sharding=csh)
            return Cell(arch_id, shape_name, cfg, fn,
                        (params_sds, frames, toks),
                        (p_shard,
                         NamedSharding(mesh, P(da, None, None)),
                         NamedSharding(mesh, P(da, None))),
                        donate=(), model=model)
        _, cspec = cache_specs(model, cfg, B, S, mesh)
        csh = shardings(cspec, mesh)
        toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
        fn = make_prefill_step(model, cfg, S, B, cache_sharding=csh)
        return Cell(arch_id, shape_name, cfg, fn, (params_sds, toks),
                    (p_shard, NamedSharding(mesh, P(da, None))),
                    donate=(), model=model)

    # decode: one new token against a seq_len cache/state
    if cfg.family == "audio":
        model.encoder_seq = 1500
    cache_sds, cspec = cache_specs(model, cfg, B, S, mesh)
    toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_spec = P(da, None) if B > 1 else P(None, None)
    fn = make_decode_step(model, cfg)
    return Cell(arch_id, shape_name, cfg, fn,
                (params_sds, cache_sds, toks),
                (p_shard, shardings(cspec, mesh),
                 NamedSharding(mesh, tok_spec)),
                donate=(1,), model=model)


def lower_cell(cell: Cell):
    return jax.jit(
        cell.fn, in_shardings=cell.in_shardings, donate_argnums=cell.donate
    ).lower(*cell.args)
