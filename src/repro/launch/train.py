"""Training launcher.

``PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 200
  [--sync allreduce|conveyor] [--replicas 2] [--scale 0.05] [--ckpt DIR]``

``--scale`` shrinks the architecture (layers/width/vocab) so real training
runs on this CPU host; the full config is exercised by the dry-run.  The
conveyor mode runs R parameter replicas coupled by the belt (Conveyor-DP) —
the paper's protocol as the DP sync layer — vs the synchronous baseline.
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import SyntheticLM
from repro.ft import FTConfig, TrainDriver
from repro.launch.conveyor_dp import ConveyorDP
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.optim.adamw import adamw_init


def scaled_config(arch: str, scale: float, seq: int):
    cfg = get_arch(arch)
    d = max(64, int(cfg.d_model * scale) // 16 * 16)
    heads = max(2, int(cfg.n_heads * scale))
    kv = max(1, min(heads, int(cfg.n_kv_heads * scale)))
    while heads % kv:
        kv -= 1
    hd = max(16, (d // heads) // 8 * 8)  # even head_dim for RoPE halves
    n_layers = max(2, int(cfg.n_layers * scale))
    attn_every = cfg.attn_every
    if cfg.family == "hybrid":
        attn_every = min(attn_every, max(1, n_layers - 1))
        n_layers = max(n_layers, attn_every + 1)
    mrope = cfg.mrope_sections
    if mrope is not None:
        half = hd // 2
        t = max(1, half // 4)
        mrope = (half - 2 * ((half - t) // 2), (half - t) // 2, (half - t) // 2)
    n_exp = min(cfg.n_experts, 8) if cfg.n_experts else 0
    top_k = min(cfg.top_k, 2) if cfg.top_k else 0
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=hd,
        d_ff=max(128, int(cfg.d_ff * scale) // 16 * 16),
        vocab=min(cfg.vocab, 2048),
        n_experts=n_exp,
        top_k=top_k,
        capacity_factor=float(n_exp) / top_k if n_exp else 1.25,  # exact MoE
        attn_every=attn_every,
        mrope_sections=mrope,
        dtype=jnp.float32,
        tp=1,
        fsdp=False,
        remat="none",
        attn_chunk=min(512, seq),
        window=min(cfg.window, seq // 2) if cfg.window else None,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--sync", choices=("allreduce", "conveyor"),
                    default="allreduce")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = scaled_config(args.arch, args.scale, args.seq)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=args.lr)
    ds = SyntheticLM(cfg.vocab, args.seq, args.batch)
    step_fn = jax.jit(make_train_step(model, opt_cfg, total_steps=args.steps))

    if args.sync == "conveyor":
        R = args.replicas
        belt = ConveyorDP(
            step_fn,
            [params] * R,
            [adamw_init(params) for _ in range(R)],
        )
        for step in range(args.steps):
            batches = [
                {k: jnp.asarray(v) for k, v in
                 ds.batch(step * R + r).items()} for r in range(R)
            ]
            ms = belt.round(batches)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss={np.mean([m['loss'] for m in ms]):.4f} "
                      f"(belt: {belt.stats.bytes_shipped/2**20:.1f}MiB shipped, "
                      f"{belt.stats.bytes_uncompressed/2**20:.1f}MiB raw)",
                      flush=True)
        belt.drain()
        print("replica drift after drain:",
              max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(belt.params[0]),
                                  jax.tree.leaves(belt.params[-1]))))
        return

    opt_state = adamw_init(params)
    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="repro_ckpt_")
    driver = TrainDriver(
        step_fn,
        lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()},
        params,
        opt_state,
        FTConfig(ckpt_dir=ckpt_dir, ckpt_every=args.ckpt_every,
                 fail_at_step=args.fail_at),
    )
    if args.resume and driver.maybe_resume():
        print(f"resumed from step {driver.step}")
    hist = driver.run(args.steps - driver.step)
    for h in hist[:: max(1, len(hist) // 10)]:
        print(f"step {h['step']:4d} loss={h['loss']:.4f} "
              f"gnorm={h['grad_norm']:.3f} {h['seconds']*1e3:.0f}ms")
    print(f"final loss {hist[-1]['loss']:.4f}  (ckpt: {ckpt_dir})")


if __name__ == "__main__":
    main()
