"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Single pod: 16×16 = 256 chips (data × model); multi-pod: 2×16×16 =
512 chips with a leading "pod" axis (data parallelism across pods, token
ring pod-major for the Conveyor-DP sync mode).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(tp: int = 1):
    """Whatever this host has — used by smoke tests / examples."""
    n = len(jax.devices())
    assert n % tp == 0
    return jax.make_mesh(
        (n // tp, tp), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def n_chips(mesh) -> int:
    import math

    return math.prod(mesh.devices.shape)
