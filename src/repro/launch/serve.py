"""Serving launcher: Operation Partitioning applied to inference.

The serving "application" is declared as transactions over the session
store and the model state, and the OFFLINE ANALYSIS (core.classify — the
actual Algorithm 1) classifies them:

    decode(session)        → LOCAL  by session id (session-sticky decode)
    open/close(session)    → LOCAL  by session id
    swap_adapter(slot)     → GLOBAL (mutates shared model state every
                             replica reads → total order via the belt)
    stats()                → COMMUTATIVE (reads immutable config)

Requests route to replica ``session % R`` exactly like belt clients; decode
batches execute immediately (no cross-replica coordination — the paper's
point); adapter swaps queue until the replica holds the token, then
replicate as state updates.  Serializability of the swap order follows from
the belt total order: every replica applies swaps in token order.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Database, TableSchema, Transaction, classify
from repro.data import SyntheticLM
from repro.models import build_model


# -- the serving app, declared for the analyzer ------------------------------

def make_serving_app():
    db = Database(
        tables=(
            TableSchema("SESSIONS", ("pos", "active"), ("sid",), (256,)),
            TableSchema("ADAPTERS", ("version",), ("slot",), (8,)),
            TableSchema("CONFIG", ("value",), ("key",), (8,), immutable=True),
            TableSchema("QPS_LOG", ("hits",), ("slot",), (16,), write_only=True),
        )
    )

    def open_session(v, p):
        v.write("SESSIONS", "active", (p["sid"],), 1)
        v.write("SESSIONS", "pos", (p["sid"],), 0)
        return p["sid"]

    def decode(v, p):
        # reads the adapter version (written by swap_adapter → global,
        # replicated) and advances this session's position.
        ver = v.read("ADAPTERS", "version", (p["slot"],))
        v.add("SESSIONS", "pos", (p["sid"],), 1)
        return ver

    def close_session(v, p):
        v.write("SESSIONS", "active", (p["sid"],), 0)
        return 0

    def swap_adapter(v, p):
        # derived second write keeps this global under any partitioning
        v.add("ADAPTERS", "version", (p["slot"],), 1)
        v.add("ADAPTERS", "version", ((p["slot"] + 1) % 8,), 0)
        return 0

    def stats(v, p):
        return v.read("CONFIG", "value", (p["key"],))

    def log_qps(v, p):
        v.add("QPS_LOG", "hits", (p["slot"],), 1)
        return 0

    txns = (
        Transaction("openSession", ("sid",), open_session, max_writes=2),
        Transaction("decode", ("sid", "slot"), decode, max_writes=1),
        Transaction("closeSession", ("sid",), close_session, max_writes=1),
        Transaction("swapAdapter", ("slot",), swap_adapter, max_writes=2),
        Transaction("stats", ("key",), stats),
        Transaction("logQps", ("slot",), log_qps, max_writes=1),
    )
    return db, txns


# -- replica group ------------------------------------------------------------


@dataclasses.dataclass
class Session:
    sid: int
    cache: object
    last_token: int


class ReplicaGroup:
    """One belt server: model params + its partition of sessions."""

    def __init__(self, model, params, max_sessions: int, max_len: int):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.sessions: dict[int, Session] = {}
        self.adapter_version = 0
        self.pending_swaps: list[int] = []

    def open(self, sid: int, prompt):
        caches = self.model.init_cache(1, self.max_len)
        logits, caches = self.model.forward_cached(
            self.params, prompt[None], caches
        )
        self.sessions[sid] = Session(sid, caches, int(jnp.argmax(logits[0])))

    def decode_batch(self, sids: list[int]) -> dict[int, int]:
        out = {}
        for sid in sids:  # per-session caches differ in fill; loop simply
            s = self.sessions[sid]
            tok = jnp.full((1, 1), s.last_token, jnp.int32)
            logits, s.cache = self.model.forward_cached(
                self.params, tok, s.cache
            )
            s.last_token = int(jnp.argmax(logits[0]))
            out[sid] = s.last_token
        return out

    def apply_swap(self, version: int):
        self.adapter_version = version  # state update: replicated swap


def serve_demo(n_replicas=2, n_sessions=8, steps=16, scale=0.05, arch="qwen3-1.7b"):
    from repro.launch.train import scaled_config

    db, txns = make_serving_app()
    cl = classify(db, txns)
    print("serving-app classification (Algorithm 1):")
    for t in txns:
        oc = cl.classes[t.name]
        print(f"  {t.name:14s} {oc.cls:2s} primary={oc.primary}")

    cfg = scaled_config(arch, scale, 64)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    groups = [ReplicaGroup(model, params, n_sessions, 256)
              for _ in range(n_replicas)]

    ds = SyntheticLM(cfg.vocab, 16, n_sessions)
    prompts = jnp.asarray(ds.batch(0)["tokens"])
    for sid in range(n_sessions):
        groups[sid % n_replicas].open(sid, prompts[sid])  # MAP routing

    token_pos, swap_version = 0, 0
    produced = {sid: [] for sid in range(n_sessions)}
    for step in range(steps):
        # local ops: every replica decodes ITS sessions, no coordination
        for r, g in enumerate(groups):
            outs = g.decode_batch(sorted(g.sessions))
            for sid, tok in outs.items():
                produced[sid].append(tok)
        # a global op now and then: queue an adapter swap at its partition
        if step % 5 == 2:
            groups[step % n_replicas].pending_swaps.append(step)
        # token hop: holder executes queued globals → replicate to all
        holder = token_pos % n_replicas
        if groups[holder].pending_swaps:
            groups[holder].pending_swaps.clear()
            swap_version += 1
            for g in groups:
                g.apply_swap(swap_version)  # passive replication
        token_pos += 1
    lens = {sid: len(v) for sid, v in produced.items()}
    versions = {r: g.adapter_version for r, g in enumerate(groups)}
    print(f"served {sum(lens.values())} tokens over {n_sessions} sessions; "
          f"adapter versions per replica: {versions} (identical ⇒ "
          f"belt-ordered swaps)")
    assert len(set(versions.values())) == 1
    return produced, versions


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--scale", type=float, default=0.05)
    args = ap.parse_args(argv)
    serve_demo(args.replicas, args.sessions, args.steps, args.scale, args.arch)


if __name__ == "__main__":
    main()
