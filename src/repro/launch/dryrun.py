import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch × shape) cell on the
production meshes and record memory / cost / collective evidence.

MUST be run as a module: ``PYTHONPATH=src python -m repro.launch.dryrun
[--arch A] [--shape S] [--multi-pod] [--single-pod] [--out results.json]``.
The XLA_FLAGS line above executes before any other import so the 512
placeholder host devices exist before jax initializes.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import all_cells  # noqa: E402
from repro.launch.mesh import make_production_mesh, n_chips  # noqa: E402
from repro.launch.roofline import collective_bytes, roofline_terms  # noqa: E402
from repro.launch.steps import build_cell, lower_cell  # noqa: E402


# Per-cell tuning shipped as deployment defaults (EXPERIMENTS.md §Perf):
# memory-bound giants use gradient accumulation, bf16 Adam moments for the
# 1T MoE, `dots` remat where it fits (I-A3), capacity 1.0 for kimi (I-B2).
_BF16_MOMENTS = __import__("repro.optim", fromlist=["AdamWConfig"]).AdamWConfig(
    moment_dtype="bfloat16"
)
CELL_TUNING = {
    ("kimi-k2-1t-a32b", "train_4k"): dict(
        microbatches=8, opt_cfg=_BF16_MOMENTS,
        overrides={"capacity_factor": 1.0},
    ),
    # exception to the no-FSDP serving default: 1T params do not fit
    # TP-only (125 GiB/chip); weight shards stay FSDP for kimi decode.
    ("kimi-k2-1t-a32b", "decode_32k"): dict(overrides={"fsdp": True}),
    ("qwen3-1.7b", "train_4k"): dict(
        microbatches=2, overrides={"remat": "dots"}
    ),
    ("phi3-medium-14b", "train_4k"): dict(microbatches=2),
    ("gemma2-9b", "train_4k"): dict(microbatches=2),
    ("zamba2-7b", "train_4k"): dict(microbatches=2),
}


def run_one(arch: str, shape: str, mesh, mesh_name: str) -> dict:
    t0 = time.time()
    tuning = CELL_TUNING.get((arch, shape), {})
    cell = build_cell(arch, shape, mesh, **tuning)
    lowered = lower_cell(cell)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "status": "ok",
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "per_device": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_live_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "collectives": coll,
    }
    rec["roofline"] = roofline_terms(rec["per_device"], coll)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true", help="2×16×16 only")
    ap.add_argument("--single-pod", action="store_true", help="16×16 only")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    meshes = []
    if not args.multi_pod:
        meshes.append(("1pod-16x16", make_production_mesh(multi_pod=False)))
    if not args.single_pod:
        meshes.append(("2pod-2x16x16", make_production_mesh(multi_pod=True)))

    results = []
    for mesh_name, mesh in meshes:
        for arch, shape, ok, why in all_cells():
            if args.arch and arch != args.arch:
                continue
            if args.shape and shape != args.shape:
                continue
            if not ok:
                results.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                                "status": "skipped", "reason": why})
                print(f"SKIP {mesh_name} {arch} {shape}: {why}", flush=True)
                continue
            try:
                rec = run_one(arch, shape, mesh, mesh_name)
                pd = rec["per_device"]
                print(
                    f"OK   {mesh_name} {arch:22s} {shape:12s} "
                    f"compile={rec['compile_s']:6.1f}s "
                    f"args={pd['argument_bytes']/2**30:7.2f}GiB "
                    f"temp={pd['temp_bytes']/2**30:7.2f}GiB "
                    f"flops/dev={pd['flops']:.3e} "
                    f"coll={sum(rec['collectives'].values())/2**20:.1f}MiB",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "error", "error": repr(e)[:2000]}
                traceback.print_exc()
                print(f"FAIL {mesh_name} {arch} {shape}: {e!r}", flush=True)
            results.append(rec)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"\n== dry-run: {n_ok} ok / {n_skip} skipped / {n_err} failed ==")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
