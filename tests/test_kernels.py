"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

key = jax.random.PRNGKey(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("B,Sq,Skv,H,Hkv,hd", [
    (1, 128, 128, 4, 4, 64),
    (2, 256, 256, 8, 2, 64),
    (1, 256, 256, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window,cap", [(None, None), (64, None), (None, 30.0)])
def test_flash_attention(B, Sq, Skv, H, Hkv, hd, dtype, window, cap):
    from repro.kernels.flash_attention.ops import flash_attention_op
    from repro.kernels.flash_attention.ref import flash_attention_ref

    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, hd), dtype)
    out = flash_attention_op(q, k, v, window=window, logit_cap=cap,
                             bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, window=window, logit_cap=cap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@pytest.mark.parametrize("B,S,H,Hkv,hd,bk", [
    (2, 512, 8, 2, 64, 128),
    (3, 256, 4, 4, 128, 64),
    (1, 1024, 16, 2, 64, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, H, Hkv, hd, bk, dtype):
    from repro.kernels.decode_attention.ops import decode_attention_op
    from repro.kernels.decode_attention.ref import decode_attention_ref

    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    kv_len = (jnp.arange(B, dtype=jnp.int32) * 37 + S // 3) % S + 1
    out = decode_attention_op(q, k, v, kv_len, bk=bk)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@pytest.mark.parametrize("Bt,S,H,P,N,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 32, 64),
    (1, 64, 8, 16, 8, 64),  # chunk > S/2 path
])
def test_mamba2_ssd(Bt, S, H, P, N, chunk):
    from repro.kernels.mamba2_ssd.ops import ssd_op
    from repro.kernels.mamba2_ssd.ref import ssd_ref

    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (Bt, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A_log = jax.random.normal(ks[2], (H,)) * 0.5
    B = jax.random.normal(ks[3], (Bt, S, N)) * 0.5
    C = jax.random.normal(ks[4], (Bt, S, N)) * 0.5
    D = jnp.full((H,), 0.3)
    out = ssd_op(xh, dt, A_log, B, C, D, chunk=min(chunk, S))
    ref = ssd_ref(xh, dt, A_log, B, C, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (2, 64, 2, 32, 16),
    (1, 128, 4, 64, 32),
])
def test_rwkv6_wkv(B, S, H, hd, chunk):
    from repro.kernels.rwkv6_wkv.ops import wkv6_op
    from repro.kernels.rwkv6_wkv.ref import wkv6_ref

    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.5))
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    y = wkv6_op(r, k, v, w, u, chunk=chunk)
    yr, _ = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("R,W,K,bt", [(256, 4, 16, 64), (512, 8, 48, 256)])
def test_delta_apply(R, W, K, bt):
    from repro.kernels.delta_apply.ops import delta_apply_op
    from repro.kernels.delta_apply.ref import delta_apply_ref

    ks = [jax.random.PRNGKey(i) for i in range(4)]
    table = jax.random.randint(ks[0], (R, W), 0, 100)
    rows = jax.random.randint(ks[1], (K,), 0, R)
    vals = jax.random.randint(ks[2], (K, W), 0, 100)
    valid = jax.random.bernoulli(ks[3], 0.8, (K,))
    out = delta_apply_op(table, rows, vals, valid, bt=bt)
    ref = delta_apply_ref(table, rows, vals, valid)
    assert jnp.array_equal(out, ref)


def test_delta_apply_duplicate_rows_token_order():
    """Later records overwrite earlier ones — the belt's serial order."""
    from repro.kernels.delta_apply.ops import delta_apply_op

    table = jnp.zeros((64, 2), jnp.int32)
    rows = jnp.array([5, 5, 5], jnp.int32)
    vals = jnp.array([[1, 1], [2, 2], [3, 3]], jnp.int32)
    valid = jnp.array([True, True, True])
    out = delta_apply_op(table, rows, vals, valid, bt=64)
    assert out[5].tolist() == [3, 3]
