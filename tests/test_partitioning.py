"""Algorithm 1 + classification (paper §3) — unit + property tests."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import Database, TableSchema, Transaction, classify
from repro.core.classify import COMMUTATIVE, DUAL, GLOBAL, LOCAL, op_partition
from repro.core.partition import optimize_partitioning, residual_clauses
from repro.core.rwsets import extract_rwsets
from repro.core.workloads import micro, rubis, tpcw


def test_paper_worked_example():
    """§3.1: createCart/doCart conflict on SC.ID becomes local under
    P = sid for both."""
    db = tpcw.make_db()
    cl = classify(db, tpcw.TXNS)
    assert cl.P["createCart"] == "sid"
    assert cl.P["doCart"] == "sid"
    assert cl.classes["createCart"].cls == LOCAL
    assert cl.classes["doCart"].cls == LOCAL


def test_tpcw_classification_matches_paper_structure():
    db = tpcw.make_db()
    cl = classify(db, tpcw.TXNS)
    c = cl.counts()
    # paper Table 1 structure: sizable local majority, few global, some
    # commutative
    assert c[LOCAL] >= 5 and c[GLOBAL] >= 2 and c[COMMUTATIVE] >= 2
    assert cl.classes["doBuyConfirm"].cls == GLOBAL  # shared stock
    assert cl.classes["adminUpdateItem"].cls == GLOBAL  # admin ops
    assert cl.classes["getStatic"].cls == COMMUTATIVE
    assert cl.classes["logClick"].cls == COMMUTATIVE


def test_rubis_dual_key():
    """§6: RUBiS storeBid uses the double-key scheme (local iff user and
    item route together)."""
    db = rubis.make_db()
    cl = classify(db, rubis.TXNS)
    oc = cl.classes["storeBid"]
    assert oc.cls == DUAL
    assert {oc.primary, oc.secondary} == {"uid", "iid"}
    # runtime dual routing
    txn = [t for t in rubis.TXNS if t.name == "storeBid"][0]
    co_routed = {"uid": 4, "iid": 8, "amt": 5}  # 4 % 4 == 8 % 4
    server, is_global = op_partition(txn, oc, co_routed, n_servers=4)
    assert not is_global
    crossed = {"uid": 4, "iid": 7, "amt": 5}
    _, is_global = op_partition(txn, oc, crossed, n_servers=4)
    assert is_global


def test_partitioning_minimizes_cost():
    db = tpcw.make_db()
    rw = {t.name: extract_rwsets(db, t) for t in tpcw.TXNS}
    P, conflicts, best = optimize_partitioning(db, tpcw.TXNS, rw)
    # the chosen P must beat the trivial no-partitioning assignment
    from repro.core.partition import cost

    none_P = {t.name: None for t in tpcw.TXNS}
    weights = {t.name: t.weight for t in tpcw.TXNS}
    assert best <= cost(none_P, conflicts, weights)


def test_local_ops_have_no_residual_violations():
    """Classification invariant: a LOCAL transaction has no residual
    cross-partition ww clause and nobody remote reads from it."""
    for wl in (tpcw, rubis, micro.make_db() and micro):
        db = wl.make_db()
        cl = classify(db, wl.TXNS)
        for t in wl.TXNS:
            if cl.classes[t.name].cls != LOCAL:
                continue
            for cf in cl.conflicts:
                if t.name not in (cf.t, cf.t2):
                    continue
                for c in residual_clauses(cf, cl.P):
                    assert c.kind != "ww", (t.name, c)
                    writer = cf.t2 if c.kind == "rf" else cf.t
                    assert writer != t.name, (t.name, c)


# -- property: generated schemas ------------------------------------------------


@st.composite
def random_app(draw):
    n_tables = draw(st.integers(1, 3))
    tables = tuple(
        TableSchema(f"T{i}", ("a", "b"), ("k",), (16,)) for i in range(n_tables)
    )
    db = Database(tables=tables)
    n_txn = draw(st.integers(2, 5))
    txns = []
    for i in range(n_txn):
        tbl = f"T{draw(st.integers(0, n_tables - 1))}"
        kind = draw(st.sampled_from(["read", "write", "rmw"]))
        attr = draw(st.sampled_from(["a", "b"]))

        def body(v, p, tbl=tbl, kind=kind, attr=attr):
            if kind == "read":
                return v.read(tbl, attr, (p["x"],))
            if kind == "write":
                v.write(tbl, attr, (p["x"],), p["y"])
                return 0
            v.add(tbl, attr, (p["x"],), p["y"])
            return 0

        txns.append(Transaction(f"t{i}", ("x", "y"), body, max_writes=1))
    return db, tuple(txns)


@settings(max_examples=25, deadline=None)
@given(random_app())
def test_classification_total_and_sound(app):
    db, txns = app
    cl = classify(db, txns)
    assert set(cl.classes) == {t.name for t in txns}
    for t in txns:
        oc = cl.classes[t.name]
        assert oc.cls in (COMMUTATIVE, LOCAL, GLOBAL, DUAL)
        if oc.cls == COMMUTATIVE:
            assert not any(t.name in (cf.t, cf.t2) for cf in cl.conflicts)
        if oc.cls == LOCAL:
            for cf in cl.conflicts:
                if t.name in (cf.t, cf.t2):
                    for c in residual_clauses(cf, cl.P):
                        writer = cf.t2 if c.kind == "rf" else cf.t
                        assert c.kind != "ww" and writer != t.name
