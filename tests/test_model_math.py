"""Model-math invariants: chunked == naive attention, SSD chunked == scan,
capacity MoE == dense reference, rope properties (hypothesis)."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.models.attention import attend
from repro.models.common import apply_mrope, apply_rope
from repro.models.ffn import moe_block
from repro.models.mamba2 import mamba2_ref_scan, ssd_chunked
from repro.kernels.flash_attention.ref import flash_attention_ref

key = jax.random.PRNGKey(0)


def test_attend_chunked_equals_naive():
    B, S, H, Hkv, hd = 2, 128, 4, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    out = attend(q, k, v, chunk=32)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_attend_decode_kv_len_mask():
    B, S, H, hd = 2, 64, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    kv_len = jnp.array([10, 20])
    out = attend(q, k, v, causal=True, q_offset=kv_len - 1, kv_len=kv_len)
    # manual: only first kv_len positions participate
    for b in range(B):
        ref = flash_attention_ref(
            q[b : b + 1], k[b : b + 1, : int(kv_len[b])],
            v[b : b + 1, : int(kv_len[b])], causal=False,
        )
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref[0]),
                                   atol=1e-5, rtol=1e-5)


def test_ssd_chunked_equals_scan():
    Bt, S, H, P, N = 2, 96, 3, 16, 8
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (Bt, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bt, S, H)))
    A_log = jax.random.normal(ks[2], (H,)) * 0.5
    B = jax.random.normal(ks[3], (Bt, S, N)) * 0.5
    C = jax.random.normal(ks[4], (Bt, S, N)) * 0.5
    D = jnp.full((H,), 0.5)
    y, _ = ssd_chunked(xh, dt, A_log, B, C, D, chunk=32)
    ref = mamba2_ref_scan(xh, dt, A_log, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_moe_capacity_equals_dense_reference():
    T, D, F, E, K = 32, 16, 24, 4, 2
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (T, D))
    rw = jax.random.normal(ks[1], (D, E)) * 0.1
    wg = jax.random.normal(ks[2], (E, D, F)) * 0.1
    wu = jax.random.normal(ks[3], (E, D, F)) * 0.1
    wd = jax.random.normal(ks[4], (E, F, D)) * 0.1
    out, _ = moe_block(x, rw, wg, wu, wd, top_k=K, capacity_factor=float(E) / K)
    probs = jax.nn.softmax(x @ rw, -1)
    gv, ei = jax.lax.top_k(probs, K)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros((T, D))
    for t in range(T):
        for k_ in range(K):
            e = int(ei[t, k_])
            h = jax.nn.silu(x[t] @ wg[e]) * (x[t] @ wu[e])
            ref = ref.at[t].add(gv[t, k_] * (h @ wd[e]))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_moe_capacity_drops_overflow_gracefully():
    """With capacity_factor ≪ 1 output stays finite and gradients flow."""
    T, D, F, E, K = 64, 8, 8, 4, 2
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (T, D))
    args = [jax.random.normal(k, s) * 0.1 for k, s in zip(
        ks[1:], [(D, E), (E, D, F), (E, D, F), (E, F, D)])]
    out, aux = moe_block(x, *args, top_k=K, capacity_factor=0.25)
    assert np.isfinite(np.asarray(out)).all() and np.isfinite(float(aux))
    g = jax.grad(lambda x: moe_block(x, *args, top_k=K,
                                     capacity_factor=0.25)[0].sum())(x)
    assert np.isfinite(np.asarray(g)).all()


@settings(max_examples=10, deadline=None)
@given(shift=st.integers(1, 64))
def test_rope_relative_position_property(shift):
    """RoPE invariant: ⟨rope(q,p+s), rope(k,p'+s)⟩ = ⟨rope(q,p), rope(k,p')⟩
    — attention scores depend only on relative offsets."""
    hd = 32
    ks = jax.random.split(jax.random.PRNGKey(shift), 2)
    q = jax.random.normal(ks[0], (1, 1, 1, hd))
    k = jax.random.normal(ks[1], (1, 1, 1, hd))
    p = jnp.array([[5]])
    p2 = jnp.array([[13]])
    a = jnp.sum(apply_rope(q, p) * apply_rope(k, p2))
    b = jnp.sum(apply_rope(q, p + shift) * apply_rope(k, p2 + shift))
    np.testing.assert_allclose(float(a), float(b), atol=1e-3, rtol=1e-3)


def test_mrope_reduces_to_rope_on_text():
    """With t=h=w position (text tokens), M-RoPE == 1-D RoPE."""
    hd = 32
    q = jax.random.normal(key, (1, 4, 2, hd))
    pos = jnp.arange(4)[None]
    pos3 = jnp.broadcast_to(pos, (3, 1, 4))
    a = apply_mrope(q, pos3, (8, 4, 4), theta=1e4)
    b = apply_rope(q, pos, theta=1e4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
