"""Elastic scaling: a checkpoint written under one mesh restores onto a
DIFFERENT device count/mesh (subprocess pair sharing a tmp dir)."""
import subprocess
import sys
import textwrap


def _run(n_devices: int, ckpt_dir: str, phase: str):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpointing import restore, save

        mesh = jax.make_mesh(({n_devices},), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        sh = NamedSharding(mesh, P("data", None))
        params = {{"w": jnp.arange(64., dtype=jnp.float32).reshape(8, 8) * 3}}
        if "{phase}" == "save":
            placed = jax.device_put(params["w"], sh)
            save("{ckpt_dir}", 5, {{"params": {{"w": placed}}}})
            print("SAVED")
        else:
            like = {{"params": {{"w": jnp.zeros((8, 8), jnp.float32)}}}}
            out = restore("{ckpt_dir}", 5, like,
                          {{"params": {{"w": sh}}}})
            got = np.asarray(out["params"]["w"])
            assert np.array_equal(got, np.asarray(params["w"])), got
            # restored leaf really is sharded over THIS mesh
            assert len(out["params"]["w"].sharding.device_set) == {n_devices}
            print("RESTORED")
    """)
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, cwd="/root/repo", timeout=300)


def test_checkpoint_elastic_reshard(tmp_path):
    """Save on 4 devices → restore on 2 (scale-down) and 8 (scale-up)."""
    ck = str(tmp_path / "ck")
    out = _run(4, ck, "save")
    assert out.returncode == 0 and "SAVED" in out.stdout, out.stderr[-1500:]
    for n in (2, 8):
        out = _run(n, ck, "restore")
        assert out.returncode == 0 and "RESTORED" in out.stdout, (
            n, out.stderr[-1500:]
        )
