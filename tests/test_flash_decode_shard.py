"""§Perf I-C1 regression: the flash-decode sequence-sharded layout must be
numerically identical to the replicated layout (it only changes shardings),
verified on a real 8-device mesh in a subprocess."""
import subprocess
import sys
import textwrap


def test_seq_sharded_decode_parity():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.train import scaled_config
        from repro.models import build_model

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        # kv heads NOT divisible by tp=4 → kv replicated → seq-shard path
        cfg = scaled_config("qwen3-1.7b", 0.1, 64)
        cfg = dataclasses.replace(cfg, tp=4, n_heads=4, n_kv_heads=1,
                                  head_dim=32)
        assert not cfg.kv_sharded

        B, S = 4, 32
        toks = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0, cfg.vocab)

        def run(with_mesh):
            model = build_model(cfg, mesh=mesh if with_mesh else None)
            params, _ = model.init(jax.random.PRNGKey(1))
            caches = model.init_cache(B, S + 4)
            logits, caches = model.forward_cached(params, toks, caches)
            nxt = jnp.argmax(logits, -1)[:, None]
            logits2, _ = model.forward_cached(params, nxt, caches)
            return np.asarray(logits2)

        a = run(False)   # no mesh → pins are no-ops, replicated math
        b = run(True)    # mesh → seq-sharded flash-decode layout
        np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
