"""Conveyor Belt protocol (paper §4, Theorem 1): serializability under the
in-JAX belt, across workloads, server counts, and op mixes."""
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import (
    Engine,
    EngineSpec,
    check_serializable,
    classify,
    run_workload,
)
from repro.core.workloads import micro, rubis, tpcw


def _run(wl, n_servers, ops, init=None, **spec_kw):
    db = wl.make_db()
    cl = classify(db, wl.TXNS)
    spec = EngineSpec(n_servers=n_servers, batch=4, queue_cap=32,
                      token_cap=256, **spec_kw)
    eng = Engine(db, wl.TXNS, cl, spec)
    init_state = db.init_state(init)
    belt, results = run_workload(eng, init_state, ops)
    check_serializable(db, eng, init_state, belt, results)
    return belt, results


@pytest.mark.parametrize("n_servers", [1, 2, 5])
def test_micro_serializable(n_servers):
    ops = micro.sample_ops(30, local_ratio=0.6, seed=n_servers)
    _, results = _run(micro, n_servers, ops)
    assert len(results) == 30


def test_tpcw_serializable():
    ops = tpcw.sample_ops(50, seed=11)
    _, results = _run(tpcw, 4, ops, init=tpcw.init_arrays())
    assert any(r.is_global for r in results)
    assert any(not r.is_global for r in results)


def test_rubis_serializable_with_dual_keys():
    ops = rubis.sample_ops(50, seed=3)
    _, results = _run(rubis, 3, ops, init=rubis.init_arrays())
    bids = [r for r in results if r.txn == "storeBid"]
    assert bids, "mix should include bids"
    # dual-key ops appear both as local (co-routed) and global over a stream
    kinds = {r.is_global for r in bids}
    assert kinds == {True, False} or len(bids) < 4


def test_global_ops_totally_ordered():
    ops = micro.sample_ops(40, local_ratio=0.2, seed=7)
    _, results = _run(micro, 3, ops)
    gseqs = sorted(r.order_key for r in results if r.is_global)
    assert gseqs == list(range(len(gseqs))), "token order must be gap-free"


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 1000),
    n_servers=st.integers(1, 4),
    ratio=st.floats(0.0, 1.0),
)
def test_serializability_property(seed, n_servers, ratio):
    ops = micro.sample_ops(24, local_ratio=ratio, seed=seed)
    _run(micro, n_servers, ops)


def test_commutative_ops_never_coordinate():
    """Commutative/log ops must execute in phase A (never stamped global)."""
    ops = [("logClick", {"slot": i % 8}) for i in range(12)]
    _, results = _run(tpcw, 3, ops, init=tpcw.init_arrays())
    assert not any(r.is_global for r in results)
