"""Host-level simulator (paper §7 evaluation machinery): sanity + paper-
shaped qualitative results."""
import numpy as np

from repro.core import Engine, EngineSpec, classify
from repro.core.hostsim import (
    SimOp,
    latency,
    op_source_from_workload,
    peak_throughput,
    simulate,
)
from repro.core.workloads import micro, tpcw


def _const_source(is_global=False, n=4, read_only=False):
    ops = [SimOp(is_global, h, read_only, (h,)) for h in range(n)]

    def src(rng):
        return ops[int(rng.integers(n))]

    return src


def test_latency_matrix():
    lan = latency(4, wan=False)
    wan = latency(5, wan=True)
    assert lan.max() <= 1.0 and np.allclose(np.diag(lan), 0)
    assert wan[0, 1] == 253.0 and wan[1, 0] == 253.0  # paper Table 2 G↔J


def test_local_ops_scale_linearly():
    src = _const_source(n=8)
    t1 = simulate("conveyor", src, 1, 32, duration_ms=5000).throughput
    t8 = simulate("conveyor", src, 8, 256, duration_ms=5000).throughput
    assert t8 > 4 * t1


def test_conveyor_beats_twopc_on_tpcw():
    """Paper Fig. 3 qualitative claim."""
    db = tpcw.make_db()
    cl = classify(db, tpcw.TXNS)
    eng = Engine(db, tpcw.TXNS, cl, EngineSpec(n_servers=8))
    src = op_source_from_workload(eng, tpcw.sample_ops(2000, seed=1), 8)
    tc, _ = peak_throughput("conveyor", src, 8, client_grid=(32, 128),
                            duration_ms=5000)
    tp, _ = peak_throughput("twopc", src, 8, client_grid=(32, 128),
                            duration_ms=5000)
    assert tc > 1.5 * tp, (tc, tp)


def test_wan_conveyor_beats_centralized():
    """Paper Fig. 4 qualitative claim: under load, Eliá's peak WAN
    throughput beats the centralized server (which saturates), and local
    ops complete at intra-site latency."""
    db = micro.make_db()
    cl = classify(db, micro.TXNS)
    eng = Engine(db, micro.TXNS, cl, EngineSpec(n_servers=5))
    src = op_source_from_workload(
        eng, micro.sample_ops(2000, local_ratio=0.8, seed=2), 5
    )
    tc, rc = peak_throughput("conveyor", src, 5, wan=True,
                             client_grid=(128, 512, 1024), duration_ms=8000)
    tz, _ = peak_throughput("central", src, 5, wan=True,
                            client_grid=(128, 512, 1024), duration_ms=8000)
    assert tc > 1.5 * tz, (tc, tz)
    # local ops at ~intra-site latency (paper Table 3's 29–35 ms regime)
    light = simulate("conveyor", src, 5, 16, duration_ms=8000, wan=True)
    assert light.mean_local_ms < 60, light.mean_local_ms


def test_local_ratio_monotonicity():
    """Paper Fig. 5: more local ops ⇒ higher sustainable throughput."""
    db = micro.make_db()
    cl = classify(db, micro.TXNS)
    eng = Engine(db, micro.TXNS, cl, EngineSpec(n_servers=3))
    ths = []
    for ratio in (0.1, 0.5, 0.9):
        src = op_source_from_workload(
            eng, micro.sample_ops(1500, local_ratio=ratio, seed=3), 3
        )
        t, _ = peak_throughput("conveyor", src, 3, wan=True,
                               client_grid=(32, 128), duration_ms=6000)
        ths.append(t)
    assert ths[0] < ths[1] < ths[2], ths
