"""Substrate tests: optimizer, compression, data pipeline, checkpointing,
fault-tolerant driver, straggler monitor."""
import os

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.data import SyntheticLM
from repro.ft import FTConfig, StragglerMonitor, TrainDriver
from repro.ft.driver import InjectedFailure
from repro.checkpointing import latest_step, restore, save
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    int8_compress,
    int8_decompress,
)


# -- optimizer ----------------------------------------------------------------


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 20.0) < 1e-4
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-4


def test_bf16_moments_shape_and_dtype():
    params = {"w": jnp.zeros((8,), jnp.bfloat16)}
    st8 = adamw_init(params, "bfloat16")
    assert st8["m"]["w"].dtype == jnp.bfloat16


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4, max_size=16))
def test_int8_error_feedback_unbiased(vals):
    """Error feedback property: over repeated compressions of the SAME
    value, the cumulative decompressed sum approaches the true sum."""
    x = {"v": jnp.asarray(vals, jnp.float32)}
    err = None
    total = jnp.zeros_like(x["v"])
    n = 8
    for _ in range(n):
        q, s, err = int8_compress(x, err)
        total = total + int8_decompress(q, s)["v"]
    np.testing.assert_allclose(
        np.asarray(total), np.asarray(x["v"]) * n,
        atol=2 * float(jnp.max(jnp.abs(x["v"])) / 127 + 1e-6), rtol=0.05,
    )


# -- data ----------------------------------------------------------------------


def test_data_deterministic_and_sharded():
    ds = SyntheticLM(vocab=1000, seq_len=32, global_batch=8, seed=3)
    a = ds.batch(5)
    b = ds.batch(5)
    assert np.array_equal(a["tokens"], b["tokens"])
    # host shards partition the global batch
    parts = [ds.batch(5, host=h, n_hosts=4)["tokens"] for h in range(4)]
    merged = np.zeros_like(a["tokens"])
    for h, p in enumerate(parts):
        merged[h::4] = p
    assert np.array_equal(merged, a["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 1000


def test_data_is_learnable_signal():
    ds = SyntheticLM(vocab=64, seq_len=64, global_batch=4, seed=0)
    b = ds.batch(0)
    # period-64 copy structure ⇒ token t at position p equals token at p+64
    assert np.array_equal(b["tokens"][:, 0], ds.batch(0)["tokens"][:, 0])


# -- checkpoint / restart --------------------------------------------------------


def _toy_setup(lr=0.05):
    params = {"w": jnp.arange(8, dtype=jnp.float32)}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=lr, weight_decay=0.0)

    def step_fn(params, opt, batch):
        def loss(p):
            return jnp.sum((p["w"] - batch["y"]) ** 2)

        l, g = jax.value_and_grad(loss)(params)
        params, opt, gn = adamw_update(cfg, params, g, opt)
        return params, opt, {"loss": l, "grad_norm": gn}

    ds = SyntheticLM(vocab=100, seq_len=8, global_batch=1, seed=0)

    def batch_fn(s):
        return {"y": jnp.asarray(ds.batch(s)["tokens"][0, :8], jnp.float32)}

    return params, opt, jax.jit(step_fn), batch_fn


def test_checkpoint_roundtrip(tmp_path):
    params, opt, _, _ = _toy_setup()
    save(str(tmp_path), 7, {"params": params, "opt": opt})
    assert latest_step(str(tmp_path)) == 7
    like = {"params": params, "opt": opt}
    out = restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(like)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_failure_injection_and_bitexact_resume(tmp_path):
    """Kill at step 12, restart from ckpt, final state must equal an
    uninterrupted run (deterministic pipeline ⇒ bit-exact recovery)."""
    params, opt, step_fn, batch_fn = _toy_setup()

    # uninterrupted run
    ref = TrainDriver(step_fn, batch_fn, params, opt,
                      FTConfig(ckpt_dir=str(tmp_path / "ref"), ckpt_every=5))
    ref.run(20)

    # interrupted run
    d1 = TrainDriver(step_fn, batch_fn, params, opt,
                     FTConfig(ckpt_dir=str(tmp_path / "ft"), ckpt_every=5,
                              fail_at_step=12))
    with pytest.raises(InjectedFailure):
        d1.run(20)
    # "new process": fresh driver, resume from latest checkpoint
    d2 = TrainDriver(step_fn, batch_fn, params, opt,
                     FTConfig(ckpt_dir=str(tmp_path / "ft"), ckpt_every=5))
    assert d2.maybe_resume() and d2.step == 10
    d2.run(20 - d2.step)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(d2.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_async_checkpoint(tmp_path):
    params, opt, step_fn, batch_fn = _toy_setup()
    d = TrainDriver(step_fn, batch_fn, params, opt,
                    FTConfig(ckpt_dir=str(tmp_path), ckpt_every=4,
                             async_ckpt=True))
    d.run(10)
    assert latest_step(str(tmp_path)) == 10


# -- straggler -------------------------------------------------------------------


def test_straggler_detection():
    mon = StragglerMonitor(n=4, threshold=2.0)
    for r in range(6):
        for p in range(4):
            mon.observe(p, 1.0 if p != 2 else 5.0)
    plan = mon.plan()
    assert plan["stragglers"] == [2]
    assert plan["action"] == "skip_token_turn"


def test_no_false_positives():
    mon = StragglerMonitor(n=4)
    for r in range(6):
        for p in range(4):
            mon.observe(p, 1.0 + 0.01 * p)
    assert mon.plan()["stragglers"] == []
