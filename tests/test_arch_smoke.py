"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU — output shapes + no NaNs.
Full configs are exercised only via the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch.steps import make_train_step
from repro.launch.train import scaled_config
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.optim.adamw import adamw_init

SEQ, BATCH = 64, 2


def _batch(cfg, key):
    toks = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab)
    b = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        b["positions"] = jnp.broadcast_to(jnp.arange(SEQ), (3, BATCH, SEQ))
        b["vision_embeds"] = jnp.ones((BATCH, 8, cfg.d_model), jnp.float32)
        b["vision_mask"] = jnp.zeros((BATCH, SEQ), bool).at[:, 2:10].set(True)
    if cfg.family == "audio":
        b["frames"] = jnp.ones((BATCH, SEQ, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_train_step(arch):
    cfg = scaled_config(arch, 0.05, SEQ)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: not isinstance(x, dict)
    ) or True  # spec tree mirrors params (checked leaf-wise below)
    n_p = len(jax.tree.leaves(params))
    n_s = len(jax.tree.leaves(specs, is_leaf=lambda x: x is None or not isinstance(x, dict)))
    assert n_p >= 1 and n_s >= 1

    batch = _batch(cfg, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    opt = adamw_init(params)
    new_params, new_opt, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, (arch, loss)
    assert int(new_opt["step"]) == 1
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert changed, arch


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "whisper-base"])
def test_arch_decode_matches_prefill(arch):
    """Decode-with-cache must agree with a fresh full forward (last-token
    logits) — the cache paths are exact, not approximations."""
    cfg = scaled_config(arch, 0.05, SEQ)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (BATCH, 16), 0, cfg.vocab)
    kw = {}
    if cfg.family == "vlm":
        kw["positions"] = jnp.broadcast_to(jnp.arange(16), (3, BATCH, 16))
    caches = model.init_cache(BATCH, 32)
    logits_prefill, caches = model.forward_cached(params, toks, caches, **kw)

    # feed one more token via decode; compare with prefill over 17 tokens
    nxt = jnp.full((BATCH, 1), 7, jnp.int32)
    kw1 = {}
    if cfg.family == "vlm":
        kw1["positions"] = jnp.full((3, BATCH, 1), 16)
    logits_dec, _ = model.forward_cached(params, nxt, caches, **kw1)

    toks17 = jnp.concatenate([toks, nxt], axis=1)
    kw17 = {}
    if cfg.family == "vlm":
        kw17["positions"] = jnp.broadcast_to(jnp.arange(17), (3, BATCH, 17))
    caches2 = model.init_cache(BATCH, 32)
    logits_full, _ = model.forward_cached(params, toks17, caches2, **kw17)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), atol=2e-3, rtol=2e-3
    )


def test_whisper_decode_matches_prefill():
    cfg = scaled_config("whisper-base", 0.1, SEQ)
    model = build_model(cfg)
    model.encoder_seq = 24
    params, _ = model.init(jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(3), (BATCH, 24, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(4), (BATCH, 8), 0, cfg.vocab)
    logits_p, caches = model.prefill(params, frames, toks)
    nxt = jnp.full((BATCH, 1), 5, jnp.int32)
    logits_d, _ = model.forward_cached(params, nxt, caches)
    # oracle: full decoder run over 9 tokens
    enc = model.encode(params, frames)
    toks9 = jnp.concatenate([toks, nxt], axis=1)
    logits_full, _ = model._decoder(params, toks9, enc, None, 0)
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(logits_full[:, -1]),
        atol=2e-3, rtol=2e-3,
    )
