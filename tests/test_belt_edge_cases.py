"""Belt edge cases: queue/token capacity pressure, pure-global workloads,
single-server degeneration, empty rounds."""
import numpy as np

from repro.core import (
    Engine,
    EngineSpec,
    check_serializable,
    classify,
    run_workload,
)
from repro.core.workloads import micro


def _engine(n, **kw):
    db = micro.make_db()
    cl = classify(db, micro.TXNS)
    return db, Engine(db, micro.TXNS, cl, EngineSpec(n_servers=n, **kw))


def test_all_global_workload():
    db, eng = _engine(3, batch=4, queue_cap=64, token_cap=256)
    ops = micro.sample_ops(24, local_ratio=0.0, seed=9)
    init = db.init_state()
    belt, results = run_workload(eng, init, ops)
    assert all(r.is_global for r in results)
    check_serializable(db, eng, init, belt, results)


def test_all_local_workload_never_tokens():
    db, eng = _engine(3, batch=4)
    ops = micro.sample_ops(24, local_ratio=1.0, seed=10)
    init = db.init_state()
    belt, results = run_workload(eng, init, ops)
    assert not any(r.is_global for r in results)
    assert int(np.asarray(belt.token.next_gseq)) == 0  # belt stayed empty
    check_serializable(db, eng, init, belt, results)


def test_single_server_degenerates_to_serial():
    db, eng = _engine(1, batch=4)
    ops = micro.sample_ops(20, local_ratio=0.5, seed=11)
    init = db.init_state()
    belt, results = run_workload(eng, init, ops)
    check_serializable(db, eng, init, belt, results)


def test_token_capacity_overflow_detected():
    """A token too small for the global burst must raise the overflow flag
    (bounded-capacity backpressure is explicit, never silent)."""
    db, eng = _engine(2, batch=8, queue_cap=64, token_cap=4)
    ops = micro.sample_ops(40, local_ratio=0.0, seed=12)
    init = db.init_state()
    try:
        belt, results = run_workload(eng, init, ops)
    except AssertionError as e:
        assert "token overflow" in str(e) or "ops never executed" in str(e)
    else:
        # if it survived, capacity was sufficient after all — flag must be off
        assert not bool(np.asarray(belt.token.overflow))


def test_repeated_keys_same_partition():
    """Many ops on ONE key: total order must match program order at the
    owning server (FIFO within a partition)."""
    ops = [("localOp", {"k": 7, "d": i + 1}) for i in range(12)]
    db, eng = _engine(3, batch=4)
    init = db.init_state()
    belt, results = run_workload(eng, init, ops)
    check_serializable(db, eng, init, belt, results)
    # replies are prefix sums 1, 1+2, ... iff executed in program order
    want = np.cumsum([i + 1 for i in range(12)])
    got = [r.reply for r in results]
    assert got == want.tolist(), got
