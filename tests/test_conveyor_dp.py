"""Conveyor-DP (the belt as the gradient-sync layer): replica convergence,
compression accounting, and equivalence properties."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.conveyor_dp import ConveyorDP
from repro.optim import AdamWConfig, adamw_update
from repro.optim.adamw import adamw_init
from repro.data import SyntheticLM


def _setup(R, compress, lr=0.05):
    params = {"w": jnp.zeros((16,), jnp.float32)}
    cfg = AdamWConfig(lr=lr, weight_decay=0.0)

    def step_fn(params, opt, batch):
        def loss(p):
            return jnp.mean((p["w"] - batch["y"]) ** 2)

        l, g = jax.value_and_grad(loss)(params)
        params, opt, gn = adamw_update(cfg, params, g, opt)
        return params, opt, {"loss": l, "grad_norm": gn}

    belt = ConveyorDP(
        jax.jit(step_fn), [params] * R, [adamw_init(params) for _ in range(R)],
        compress=compress,
    )
    ds = SyntheticLM(vocab=64, seq_len=16, global_batch=R, seed=1)

    def batches(step):
        b = ds.batch(step)
        return [{"y": jnp.asarray(b["tokens"][r], jnp.float32)} for r in range(R)]

    return belt, batches


def test_replicas_identical_after_drain_uncompressed():
    """Additive deltas commute ⇒ after drain every replica holds the same
    parameters (the belt's agreement property for commutative updates)."""
    belt, batches = _setup(R=3, compress=False)
    for s in range(8):
        belt.round(batches(s))
    belt.drain()
    for r in range(1, 3):
        np.testing.assert_allclose(
            np.asarray(belt.params[0]["w"]), np.asarray(belt.params[r]["w"]),
            atol=1e-6,
        )


def test_compressed_drift_bounded():
    belt, batches = _setup(R=2, compress=True)
    for s in range(10):
        belt.round(batches(s))
    belt.drain()
    drift = float(jnp.max(jnp.abs(belt.params[0]["w"] - belt.params[1]["w"])))
    scale = float(jnp.max(jnp.abs(belt.params[0]["w"]))) + 1e-6
    assert drift < 0.15 * scale, (drift, scale)
    # wire savings ≈ 4× (int8 vs f32)
    assert belt.stats.bytes_shipped * 3 < belt.stats.bytes_uncompressed


def test_belt_makes_progress():
    belt, batches = _setup(R=2, compress=False, lr=0.2)
    first = belt.round(batches(0))[0]["loss"]
    for s in range(1, 25):
        last = belt.round(batches(s))
    belt.drain()
    assert last[0]["loss"] < first * 0.7, (first, last[0]["loss"])


def test_ring_delta_exchange_spmd():
    """In-JAX belt hop: int8 permute over a ring axis (multi-device)."""
    import subprocess, sys, textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.conveyor_dp import ring_delta_exchange
        mesh = jax.make_mesh((4,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        x = jnp.arange(16, dtype=jnp.float32).reshape(4, 4) + 1
        xs = jax.device_put(x, NamedSharding(mesh, P("pod", None)))
        f = jax.jit(jax.shard_map(
            lambda d: ring_delta_exchange(d, "pod", 4),
            mesh=mesh, in_specs=P("pod", None), out_specs=P("pod", None),
            check_vma=False))
        y = np.asarray(f(xs))
        want = np.roll(np.asarray(x), 1, axis=0)
        assert np.allclose(y, want, atol=np.abs(want).max() / 100), (y, want)
        txt = f.lower(xs).compile().as_text()
        assert txt.count("collective-permute(") >= 1
        # int8 on the wire: the permuted payload is s8
        assert "s8[" in txt
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_spmd_belt_equals_virtual():
    """Full protocol: shard_map deployment ≡ VirtualBelt (subprocess with 4
    host devices)."""
    import subprocess, sys, textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import classify, Engine, EngineSpec, VirtualBelt
        from repro.core.spmd import make_spmd_belt, init_spmd_state
        from repro.core.serial import make_batches
        from repro.core.workloads import micro
        db = micro.make_db()
        cl = classify(db, micro.TXNS)
        eng = Engine(db, micro.TXNS, cl,
                     EngineSpec(n_servers=4, batch=4, queue_cap=16, token_cap=64))
        mesh = jax.make_mesh((4,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        round_fn = make_spmd_belt(eng, mesh, "data")
        state = init_spmd_state(eng, db.init_state())
        sh = lambda tree: jax.tree.map(
            lambda a: jax.device_put(a, NamedSharding(
                mesh, P("data", *([None] * (a.ndim - 1))))), tree)
        dbs, queues, tokens, applied = [sh(x) for x in state]
        vb = VirtualBelt(eng, db.init_state())
        ops = micro.sample_ops(24, local_ratio=0.5, seed=5)
        pending = [(i, t, p) for i, (t, p) in enumerate(ops)]
        for rnd in range(14):
            take, pending = pending[:6], pending[6:]
            batch, lo = make_batches(eng, take, rnd)
            pending = lo + pending
            dbs, queues, tokens, applied, *_ = round_fn(
                dbs, queues, tokens, applied, rnd, sh(batch))
            vb.run_round(batch)
        v, s = jax.device_get(vb.dbs), jax.device_get(dbs)
        for k in v.arrays:
            assert np.array_equal(v.arrays[k], s.arrays[k]), k
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
