"""Serving example (deliverable b): batched decode across replica groups
with Operation-Partitioning request routing — session-sticky local decode,
belt-ordered global adapter swaps.

Run:  PYTHONPATH=src python examples/serve_partitioned.py
"""
from repro.launch.serve import serve_demo

if __name__ == "__main__":
    produced, versions = serve_demo(
        n_replicas=2, n_sessions=8, steps=24, scale=0.05
    )
    assert all(len(v) == 24 for v in produced.values())
    print("sessions decoded 24 tokens each; adapter versions consistent:",
          versions)
