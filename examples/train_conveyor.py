"""End-to-end driver (deliverable b): train a ~100M-param model for a few
hundred steps under BOTH sync modes — synchronous all-reduce vs Conveyor-DP
(the paper's belt as the gradient-sync layer) — and compare loss + wire
bytes.

Run:  PYTHONPATH=src python examples/train_conveyor.py [--steps 200]
(~100M params: scaled qwen3 at --scale 0.35 ⇒ d_model 704, 9 layers.)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLM
from repro.launch.conveyor_dp import ConveyorDP
from repro.launch.steps import make_train_step
from repro.launch.train import scaled_config
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.optim.adamw import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--scale", type=float, default=0.35)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = scaled_config("qwen3-1.7b", args.scale, args.seq)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.n_layers}L d={cfg.d_model} → {n/1e6:.0f}M params")

    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=6e-4),
                                      total_steps=args.steps))
    ds = SyntheticLM(cfg.vocab, args.seq, args.batch)

    # -- synchronous baseline (one logical step over 2x batch) ---------------
    ds2 = SyntheticLM(cfg.vocab, args.seq, 2 * args.batch)
    p, o = params, adamw_init(params)
    t0 = time.time()
    for s in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in ds2.batch(s).items()}
        p, o, m = step_fn(p, o, b)
        if s % 50 == 0:
            print(f"  [sync]     step {s:4d} loss {float(m['loss']):.4f}")
    sync_loss, sync_t = float(m["loss"]), time.time() - t0

    # -- Conveyor-DP: 2 replicas, int8 deltas on the belt ---------------------
    belt = ConveyorDP(step_fn, [params] * 2,
                      [adamw_init(params) for _ in range(2)])
    t0 = time.time()
    for s in range(args.steps):
        bs = [{k: jnp.asarray(v) for k, v in ds.batch(2 * s + r).items()}
              for r in range(2)]
        ms = belt.round(bs)
        if s % 50 == 0:
            print(f"  [conveyor] step {s:4d} loss "
                  f"{np.mean([m['loss'] for m in ms]):.4f}")
    belt.drain()
    belt_loss = np.mean([m["loss"] for m in ms])
    belt_t = time.time() - t0

    print(f"\nsync:     final loss {sync_loss:.4f}  ({sync_t:.0f}s)")
    print(f"conveyor: final loss {belt_loss:.4f}  ({belt_t:.0f}s)  wire "
          f"{belt.stats.bytes_shipped/2**20:.0f}MiB vs "
          f"{belt.stats.bytes_uncompressed/2**20:.0f}MiB uncompressed "
          f"({belt.stats.bytes_uncompressed/max(belt.stats.bytes_shipped,1):.1f}x saved)")
    drift = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(belt.params[0]),
                                jax.tree.leaves(belt.params[1])))
    print(f"replica drift after drain: {drift:.2e}")


if __name__ == "__main__":
    main()
