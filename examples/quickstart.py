"""Quickstart: classify an OLTP app with Operation Partitioning, run it on
the Conveyor Belt, verify serializability — then train a (scaled) qwen3 for
a few hundred steps with checkpoint/restart.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.core import (
    Engine,
    EngineSpec,
    check_serializable,
    classify,
    run_workload,
)
from repro.core.workloads import tpcw
from repro.data import SyntheticLM
from repro.ft import FTConfig, TrainDriver
from repro.launch.steps import make_train_step
from repro.launch.train import scaled_config
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.optim.adamw import adamw_init


def part_one_oltp():
    print("== 1. Operation Partitioning on TPC-W (paper §3) ==")
    db = tpcw.make_db()
    cl = classify(db, tpcw.TXNS)  # static analysis + Algorithm 1
    for name, oc in cl.classes.items():
        print(f"  {name:18s} class={oc.cls:2s} partition_by={oc.primary}")

    print("== 2. Conveyor Belt execution over 4 servers (paper §4) ==")
    eng = Engine(db, tpcw.TXNS, cl, EngineSpec(n_servers=4))
    init = db.init_state(tpcw.init_arrays())
    ops = tpcw.sample_ops(60, seed=0)
    belt, results = run_workload(eng, init, ops)
    n_global = sum(r.is_global for r in results)
    print(f"  executed {len(results)} ops ({n_global} global, "
          f"{len(results) - n_global} coordination-free)")
    check_serializable(db, eng, init, belt, results)
    print("  serializability check: PASSED (Theorem 1)")


def part_two_training():
    print("== 3. Train a scaled qwen3 with the FT driver ==")
    cfg = scaled_config("qwen3-1.7b", 0.05, 128)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    ds = SyntheticLM(cfg.vocab, 128, 8)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                      total_steps=300))
    driver = TrainDriver(
        step_fn,
        lambda s: {k: jnp.asarray(v) for k, v in ds.batch(s).items()},
        params,
        adamw_init(params),
        FTConfig(ckpt_dir=tempfile.mkdtemp(prefix="quickstart_"),
                 ckpt_every=100),
    )
    hist = driver.run(300)
    print(f"  step   0: loss {hist[0]['loss']:.3f}")
    print(f"  step {hist[-1]['step']}: loss {hist[-1]['loss']:.3f}")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss should decrease"
    print("  training signal: OK")


if __name__ == "__main__":
    part_one_oltp()
    part_two_training()
