"""Paper-style scaling study (Fig. 3/4 analogue) runnable in seconds: peak
throughput of the Conveyor Belt vs 2PC on TPC-W, LAN + WAN.

Run:  PYTHONPATH=src python examples/oltp_scaling.py
"""
from repro.core import Engine, EngineSpec, classify
from repro.core.hostsim import op_source_from_workload, peak_throughput
from repro.core.workloads import tpcw


def main():
    db = tpcw.make_db()
    cl = classify(db, tpcw.TXNS)
    pool = tpcw.sample_ops(3000, seed=0)
    print(f"{'N':>3} | {'conveyor LAN':>14} | {'2PC LAN':>10} | {'conveyor WAN':>14}")
    for n in (1, 2, 4, 8, 13):
        eng = Engine(db, tpcw.TXNS, cl, EngineSpec(n_servers=n))
        src = op_source_from_workload(eng, pool, n)
        tc, _ = peak_throughput("conveyor", src, n, client_grid=(32, 128, 512),
                                duration_ms=6000)
        tp, _ = peak_throughput("twopc", src, n, client_grid=(32, 128, 512),
                                duration_ms=6000)
        tw, _ = peak_throughput("conveyor", src, n, wan=True,
                                client_grid=(32, 128, 512), duration_ms=6000)
        print(f"{n:3d} | {tc:11.0f} /s | {tp:7.0f} /s | {tw:11.0f} /s")
    print("(peak throughput under the paper's 2000 ms latency bound)")


if __name__ == "__main__":
    main()
